"""Ahead-of-time executable cache for the serving path.

FILCO's real-time reconfiguration only pays off when switching compositions
is cheap; the Reconfigurable Stream Network line of work gets there by
pre-staging per-configuration programs.  The serving analog: every composed
sub-mesh shape is a distinct XLA program, and the post-recomposition
recompile (0.7-2.3 s measured) dwarfs state migration (~10 ms).  This cache
holds compiled executables keyed by (function kind, mesh fingerprint,
shape extras) so the fabric can compile a candidate composition's decode and
prefill programs *before* committing the switch — the first step on the new
composition then hits a warm executable.

jax.jit's dispatch cache cannot be warmed this way: ``.lower().compile()``
returns an executable but does not populate the dispatch path (measured: the
first traced call after an AOT compile still pays full compile time).  So
the engine calls the compiled executables directly and this cache is the
source of truth.

Thread-safe: the fabric may warm a candidate composition from a background
thread while the main thread keeps serving.  Builds happen outside the lock
(XLA compilation is thread-safe and releases the GIL); a lost race costs one
duplicate compile, never a wrong executable.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional


class ExecutableCache:
    """A small LRU of AOT-compiled executables.

    The key space is bounded in practice — one decode program per composed
    mesh a tenant has run on, plus one prefill program per (mesh, padded
    prompt length) bucket — but a long-lived fabric bouncing through many
    compositions should not hoard dead executables, hence the LRU cap.
    """

    def __init__(self, capacity: int = 32):
        self.capacity = int(capacity)
        self.builds = 0                 # cold compiles performed (telemetry)
        self.hits = 0
        self._lock = threading.Lock()
        self._exe: OrderedDict[Hashable, Any] = OrderedDict()

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            exe = self._exe.get(key)
            if exe is not None:
                self._exe.move_to_end(key)
                self.hits += 1
            return exe

    def contains(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._exe

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        exe = self.get(key)
        if exe is not None:
            return exe
        exe = builder()                 # outside the lock: compiles are slow
        self._insert(key, exe)
        return exe

    def ensure(self, key: Hashable, builder: Callable[[], Any]) -> int:
        """Warm path: build & insert iff missing.  Returns builds done (0/1)."""
        if self.contains(key):
            return 0
        self._insert(key, builder())
        return 1

    def snapshot(self) -> dict:
        """Telemetry view: cache-wide cold builds, warm hits, occupancy.
        Consumed by the fabric's metrics snapshot (the registry's
        ``exec_cache_*`` gauges) — per-engine build attribution stays with
        :attr:`EngineTelemetry.compile_builds`."""
        with self._lock:
            return {"builds": self.builds, "hits": self.hits,
                    "size": len(self._exe), "capacity": self.capacity}

    def _insert(self, key: Hashable, exe: Any) -> None:
        with self._lock:
            if key not in self._exe:
                self.builds += 1
            self._exe[key] = exe
            self._exe.move_to_end(key)
            while len(self._exe) > self.capacity:
                self._exe.popitem(last=False)
