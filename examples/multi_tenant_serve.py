"""Composed accelerators (FILCO §1/§2.1): partition one device mesh into
independent sub-accelerators serving DIFFERENT models concurrently, then
re-unify it for a single large job.

This is the pod-scale face of FILCO's "unified or multiple independent
accelerators": the MeshComposer carves the model axis; each tenant engine
runs on its own sub-mesh.

Run (fakes 8 devices; ONLY examples/dry-run may do this):
  PYTHONPATH=src python examples/multi_tenant_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.core.composer import MeshComposer  # noqa: E402
from repro.distribution import strip  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    comp = MeshComposer(mesh, cu_axis="model")
    print(f"fabric: {mesh.devices.size} devices on axis 'model'")

    # --- composed: two tenants on disjoint sub-accelerators ---------------
    sub_a, sub_b = comp.compose([4, 4], names=["tenant-A", "tenant-B"])
    tenants = [("tenant-A (minitron)", sub_a, "minitron-4b"),
               ("tenant-B (qwen2.5)", sub_b, "qwen2.5-32b")]
    rng = np.random.default_rng(0)
    for name, sub, arch in tenants:
        cfg = get_reduced(arch)
        model = build_model(cfg)
        params = strip(model.init(jax.random.key(0)))
        toks = rng.integers(1, cfg.vocab_size, size=(2, 12)).astype(np.int32)
        with sub.mesh:
            cache = strip(model.init_cache(2, 32))
            logits, cache = jax.jit(
                lambda p, t, c: model.prefill(p, {"tokens": t}, c)
            )(params, toks, cache)
        print(f"{name}: devices={sub.mesh.devices.size} "
              f"cu_ids={sub.cu_ids} first_tokens={np.argmax(np.asarray(jax.device_get(logits)), -1)}")

    # --- unified: the whole fabric as one accelerator ----------------------
    uni = comp.unified()
    cfg = get_reduced("granite-34b")
    model = build_model(cfg)
    params = strip(model.init(jax.random.key(1)))
    toks = rng.integers(1, cfg.vocab_size, size=(4, 12)).astype(np.int32)
    with uni.mesh:
        loss, _ = jax.jit(lambda p, t: model.loss(
            p, {"tokens": t, "labels": t}))(params, toks)
    print(f"unified: devices={uni.mesh.devices.size} granite loss={float(loss):.3f}")
    print("multi-tenant composition OK")


if __name__ == "__main__":
    main()
