"""Public wrappers for the fused selective scan / step with CPU fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import kernel as K
from repro.kernels.mamba_scan import ref as R


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def selective_scan_fused(x, dt, b, c, a_log, d, *, bd=512, bs=128, impl="auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.mamba_scan_ref(x, dt, b, c, a_log, d)
    interpret = impl == "interpret" or not _on_tpu()
    return K.mamba_scan(x, dt, b, c, a_log, d, bd=bd, bs=bs,
                        interpret=interpret)


def mamba_step_fused(x1, conv, h, in_proj, conv_w, conv_b, x_proj, dt_proj,
                     dt_bias, a_log, d, out_proj, *, live=None, impl="auto"):
    """Fused single-token Mamba step (SSMEngine decode hot path).

    x1: (B, 1, d_model) -> (out, new_conv, new_h); live optionally marks
    empty slots (no work, state unchanged).  Live rows are bit-identical to
    the unfused ``repro.models.ssm.mamba_step`` chain."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.mamba_step_ref(x1, conv, h, in_proj, conv_w, conv_b, x_proj,
                                dt_proj, dt_bias, a_log, d, out_proj,
                                live=live)
    interpret = impl == "interpret" or not _on_tpu()
    live_i = (jnp.ones((x1.shape[0],), jnp.int32) if live is None
              else jnp.asarray(live).astype(jnp.int32))
    return K.mamba_step_kernel(x1, conv, h, live_i, in_proj, conv_w, conv_b,
                               x_proj, dt_proj, dt_bias, a_log, d, out_proj,
                               interpret=interpret)
