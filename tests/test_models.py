"""Per-architecture smoke tests (deliverable (f)): every assigned arch
instantiates its REDUCED config and runs one forward/train step on CPU,
asserting output shapes and finiteness; plus decode-vs-prefill consistency
(the serving path computes the same function as the parallel path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced, cells_for
from repro.distribution import strip
from repro.models import build_model

B, S = 2, 16


def _batch(cfg, rng_key=1):
    tokens = jax.random.randint(jax.random.key(rng_key), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(jax.random.key(rng_key + 1),
                                            (B, S, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            m = build_model(cfg)
            cache[arch] = (cfg, m, strip(m.init(jax.random.key(0))))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss(models, arch):
    cfg, m, params = models(arch)
    loss, metrics = m.loss(params, _batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    assert jnp.isfinite(metrics["xent"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(models, arch):
    """One optimizer step: params change, everything stays finite."""
    from repro.optim import make_optimizer
    from repro.train.trainer import TrainConfig, make_train_step

    cfg, m, params = models(arch)
    opt = make_optimizer(cfg.optimizer)
    step = make_train_step(m, opt, TrainConfig(steps=4, lr=1e-3, warmup=1))
    opt_state = opt.init(params)
    # step 1: the cosine schedule's lr is 0 at step 0 (warmup ramp)
    new_params, _, metrics = step(params, opt_state, jnp.asarray(1),
                                  _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0.0, f"{arch}: params did not move"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(models, arch):
    cfg, m, params = models(arch)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    sl = S if cfg.is_encdec else 0
    if cfg.is_encdec:
        batch["frames"] = batch["frames"]
    full_logits, _ = m.prefill(params, batch,
                               strip(m.init_cache(B, 2 * S, src_len=sl)))
    k = S // 2
    cache = strip(m.init_cache(B, 2 * S, src_len=sl))
    pre = dict(batch)
    pre["tokens"] = tokens[:, :k]
    logits, cache = m.prefill(params, pre, cache)
    for i in range(k, S):
        logits, cache = m.decode_step(params, cache, tokens[:, i:i + 1])
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32)
                                - full_logits.astype(jnp.float32))))
    assert err < 2e-1, (arch, err)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "granite-34b",
                                  "deepseek-v2-lite-16b", "chameleon-34b"])
def test_padded_prefill_matches_exact(models, arch):
    """true_len-masked padded prefill == exact-length prefill (attention
    archs only; SSM state is padding-sensitive by design — engine uses
    exact-length prefill there)."""
    cfg, m, params = models(arch)
    batch = _batch(cfg)
    k, pad = 10, 6
    exact = dict(batch)
    exact["tokens"] = batch["tokens"][:, :k]
    le, _ = m.prefill(params, exact, strip(m.init_cache(B, 2 * S)))
    padded = dict(batch)
    padded["tokens"] = jnp.concatenate(
        [batch["tokens"][:, :k], jnp.zeros((B, pad), jnp.int32)], axis=1)
    lp, _ = m.prefill(params, padded, strip(m.init_cache(B, 2 * S)),
                      true_len=k)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(le, np.float32), atol=1e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_shapes(arch):
    """The FULL configs are exercised abstractly (no allocation): eval_shape
    the init and one loss; assert the declared parameter count matches the
    materialized tree within 2%."""
    cfg = get_config(arch)
    m = build_model(cfg)
    shapes = jax.eval_shape(m.init, jax.random.key(0))
    total = sum(int(np.prod(l.value.shape)) for l in jax.tree.leaves(
        shapes, is_leaf=lambda x: hasattr(x, "logical")))
    declared = cfg.param_count()
    assert abs(total - declared) / declared < 0.02, (arch, total, declared)


def test_cells_for_documented_skips():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md §4)."""
    long_archs = {a for a in ARCH_IDS
                  if any(c.name == "long_500k"
                         for c in cells_for(get_config(a)))}
    assert long_archs == {"hymba-1.5b", "falcon-mamba-7b"}
    for a in ARCH_IDS:
        names = [c.name for c in cells_for(get_config(a))]
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_continuous_batching_vector_positions(models):
    """Slots at different cache depths decode correctly in one batch."""
    cfg, m, params = models("minitron-4b")
    tokens = jax.random.randint(jax.random.key(3), (2, 12), 0, cfg.vocab_size)
    # row 0 prefilled with 8 tokens, row 1 with 5 (padded prefill+true_len)
    cache = strip(m.init_cache(2, 24))
    padded = jnp.where(jnp.arange(12)[None, :] <
                       jnp.asarray([[8], [5]]), tokens, 0)
    _, cache = m.prefill(params, {"tokens": padded}, cache,
                         true_len=jnp.asarray([8, 5]))
    # decode one token per row; compare against per-row references
    nxt = tokens[:, [8]] * 0 + 7
    logits, _ = m.decode_step(params, cache, nxt)
    for r, plen in enumerate((8, 5)):
        c1 = strip(m.init_cache(1, 24))
        _, c1 = m.prefill(params, {"tokens": tokens[r:r + 1, :plen]}, c1)
        ref, _ = m.decode_step(params, c1, nxt[r:r + 1])
        np.testing.assert_allclose(np.asarray(logits[r], np.float32),
                                   np.asarray(ref[0], np.float32), atol=1e-2)
