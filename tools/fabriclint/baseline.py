"""Baseline: the accepted-findings ledger.

Each entry records one deliberate violation with a *reason string* — e.g.
the TTFT read-back in ``_prefill_into_slot`` is a sync the hot-sync rule
sees, and the baseline is where that judgment call lives, reviewable in the
diff like code.  Entries match findings on the line-number-free fingerprint
(rule, path, symbol, code), so unrelated edits to a file never invalidate
them; entries that stop matching anything are reported stale so the ledger
shrinks as violations are actually fixed.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

KEYS = ("rule", "path", "symbol", "code")


def load(path: Optional[Path]) -> List[Dict[str, str]]:
    if path is None or not Path(path).exists():
        return []
    data = json.loads(Path(path).read_text())
    entries = data.get("entries", data) if isinstance(data, dict) else data
    for e in entries:
        missing = [k for k in KEYS if k not in e]
        if missing:
            raise ValueError(f"baseline entry {e!r} missing {missing}")
    return entries


def save(path: Path, entries: Sequence[Dict[str, str]]) -> None:
    ordered = sorted(entries, key=lambda e: tuple(e[k] for k in KEYS))
    Path(path).write_text(json.dumps(
        {"entries": ordered}, indent=2, sort_keys=True) + "\n")


def entry_for(finding, reason: str) -> Dict[str, str]:
    return {"rule": finding.rule, "path": finding.path,
            "symbol": finding.symbol, "code": finding.code,
            "reason": reason}


def apply(findings, entries):
    """Split ``findings`` against the baseline.

    Returns ``(new, baselined, stale)``: findings with no entry, (finding,
    reason) pairs an entry absorbed, and entries that matched nothing.
    """
    table: Dict[Tuple[str, str, str, str], Dict[str, str]] = {
        tuple(e[k] for k in KEYS): e for e in entries}
    used = set()
    new, baselined = [], []
    for f in findings:
        entry = table.get(f.fingerprint())
        if entry is None:
            new.append(f)
        else:
            used.add(f.fingerprint())
            baselined.append((f, entry.get("reason", "")))
    stale = [e for key, e in table.items() if key not in used]
    return new, baselined, stale
