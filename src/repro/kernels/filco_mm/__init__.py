from repro.kernels.filco_mm.kernel import (
    atoms_issued_flexible,
    atoms_issued_static,
)
from repro.kernels.filco_mm.ops import flex_mm, static_mm

__all__ = ["flex_mm", "static_mm", "atoms_issued_flexible", "atoms_issued_static"]
