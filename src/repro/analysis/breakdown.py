"""Perf-iteration profiling: attribute trip-count-weighted HLO cost to
computations and ops — the 'profile' of the dry-run methodology (no wall
clock on CPU; the lowered IR is the instrument).

  PYTHONPATH=src python -m repro.analysis.breakdown --arch X --cell Y \
      [--multi-pod] [--ssm-impl fused] [--top 12]

Prints the top computations by (bytes x multiplier) and (flops x
multiplier), plus per-op class totals — this is what each EXPERIMENTS.md
§Perf hypothesis is formed from.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.analysis.hlo import (COLLECTIVES, HloAnalyzer, _CALL_ATTR,
                                _OP_LINE, _OPERAND, _TRIP, _WHILE_ATTR,
                                _shape_numel_bytes)


def multipliers(a: HloAnalyzer) -> Dict[str, int]:
    """Execution multiplier per computation (product of while trip counts
    along the call chain from entry)."""
    mult: Dict[str, int] = {a.entry: 1}
    stack = [a.entry]
    while stack:
        comp = stack.pop()
        for line in a.computations.get(comp, []):
            m = _OP_LINE.match(line)
            if not m:
                continue
            if m.group(3) == "while":
                mw = _WHILE_ATTR.search(line)
                mt = _TRIP.search(line)
                trip = int(mt.group(1)) if mt else 1
                if mw:
                    for child in (mw.group(1), mw.group(2)):
                        if child not in mult:
                            mult[child] = 0
                            stack.append(child)
                        mult[child] += mult[comp] * trip
            elif m.group(3) in ("call", "conditional"):
                for child in _CALL_ATTR.findall(line):
                    if child in a.computations and child not in mult:
                        mult[child] = mult[comp]
                        stack.append(child)
    return mult


def own_cost(a: HloAnalyzer, name: str) -> Tuple[float, float, Dict[str, float]]:
    """(bytes, flops, per-op bytes) of one computation, children excluded,
    same op accounting rules as HloAnalyzer.cost()."""
    symbols = a._symbols(name)
    tot_b, tot_f = 0.0, 0.0
    by_op: Dict[str, float] = {}
    for line in a.computations.get(name, []):
        m = _OP_LINE.match(line)
        if not m:
            continue
        nm, shp, op = m.groups()
        rb = _shape_numel_bytes(shp)
        add_b = 0.0
        if op == "fusion":
            mc = _CALL_ATTR.search(line)
            body = mc.group(1) if mc else None
            if body:
                inner = a.cost(body, inside_fusion=True)
                tot_f += inner.flops
            arg_str = line.split("fusion(", 1)[1] if "fusion(" in line \
                else line.split("(", 1)[1]
            opnds = _OPERAND.findall(arg_str.split("), ")[0] + ")")
            w = a._dus_window(body) if body else None
            if w is not None:
                from repro.analysis.hlo import _SHAPE_ATOM
                elems = [_shape_numel_bytes(f"{dt}[{dims}]")
                         for dt, dims in _SHAPE_ATOM.findall(shp)]
                max_elem = max(elems) if elems else rb
                add_b = 2.0 * w + sum(
                    _shape_numel_bytes(symbols.get(o, "")) for o in opnds
                    if _shape_numel_bytes(symbols.get(o, "")) < max_elem)
            else:
                sl = a._fusion_sliced_params(body) if body else {}
                add_b = rb
                for i, o in enumerate(opnds):
                    full = _shape_numel_bytes(symbols.get(o, ""))
                    add_b += min(full, sl.get(i, full))
        elif op in ("parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "copy", "while"):
            pass
        elif op in ("slice", "dynamic-slice", "gather"):
            add_b = 2.0 * rb
        elif op == "dynamic-update-slice":
            ops_ = _OPERAND.findall(line.split("(", 1)[1])
            upd = _shape_numel_bytes(symbols.get(ops_[1], "")) \
                if len(ops_) > 1 else rb
            add_b = 2.0 * upd
        else:
            opnds = _OPERAND.findall(
                line.split("(", 1)[1]) if "(" in line else []
            add_b = rb + sum(_shape_numel_bytes(symbols.get(o, ""))
                             for o in opnds)
            if op == "dot":
                tot_f += a._dot_flops(line, symbols, shp)
        tot_b += add_b
        by_op[op] = by_op.get(op, 0.0) + add_b
    return tot_b, tot_f, by_op


def report(text: str, top: int = 12) -> str:
    a = HloAnalyzer(text)
    mult = multipliers(a)
    rows = []
    op_totals: Dict[str, float] = {}
    for name, m in mult.items():
        b, f, by_op = own_cost(a, name)
        rows.append((b * m, f * m, m, name))
        for op, v in by_op.items():
            op_totals[op] = op_totals.get(op, 0.0) + v * m
    rows.sort(reverse=True)
    out = [f"{'bytes(TB)':>10s} {'flops(T)':>9s} {'xmult':>6s}  computation"]
    for b, f, m, name in rows[:top]:
        out.append(f"{b/1e12:10.3f} {f/1e12:9.3f} {m:6d}  {name[:70]}")
    out.append("")
    out.append("per-op bytes (x multiplier):")
    for op, v in sorted(op_totals.items(), key=lambda kv: -kv[1])[:top]:
        out.append(f"  {op:22s} {v/1e12:10.3f} TB")
    c = a.cost()
    out.append("")
    out.append(f"totals/device: flops={c.flops:.3e} bytes={c.bytes/1e12:.2f}TB "
               f"collective={c.collective_bytes/1e9:.1f}GB "
               f"{dict((k, round(v/1e9,1)) for k,v in c.collective_by_kind.items())}")
    return "\n".join(out)


def main():
    import argparse
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax

    from repro.configs import ARCH_IDS, CELLS_BY_NAME, get_config
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--cell", choices=sorted(CELLS_BY_NAME), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attn-impl", default="blockwise")
    ap.add_argument("--moe-dispatch", default="einsum")
    ap.add_argument("--ssm-impl", default="chunked")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, kwargs, out_sh = build_cell(cfg, CELLS_BY_NAME[args.cell], mesh,
                                    attn_impl=args.attn_impl,
                                    moe_dispatch=args.moe_dispatch,
                                    ssm_impl=args.ssm_impl)
    with mesh:
        comp = jax.jit(fn, out_shardings=out_sh).lower(**kwargs).compile()
    print(report(comp.as_text(), top=args.top))


if __name__ == "__main__":
    main()
