"""hymba-1.5b — hybrid parallel attention+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer computes attention and a Mamba head in parallel on the same input
and fuses the two normalized outputs (Hymba §2.1).  Attention is sliding-window
except for 3 global layers (first / middle / last), which keeps `long_500k`
sub-quadratic (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    attn_type="sliding",
    window_size=1024,
    global_attn_layers=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    hybrid_parallel=True,
    act="silu",
    glu=True,
)

REDUCED = ModelConfig(
    name="hymba-reduced",
    family="hybrid",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    attn_type="sliding",
    window_size=8,
    global_attn_layers=(0,),
    ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
    hybrid_parallel=True,
    act="silu",
    glu=True,
)
