"""Pure-jnp oracle for ragged decode attention.

Mirrors :func:`repro.models.layers.decode_attention` op-for-op (same einsums,
same mask order, same NEG_INF fill), so its live rows are bit-identical to
the padded serving path — the drop-in contract the serving engines rely on
and tests/test_ragged_decode.py pins.  On top of the padded semantics it
adds the ragged extensions the Pallas kernel implements:

* ``lengths`` may be any per-row true KV lengths (``valid_len`` for
  self-attention, ``src_len`` for cross-attention);
* ``live`` optionally marks empty slots: rows with ``live == False`` return
  exact zeros (the kernel skips their KV reads entirely).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ragged_decode_attention_ref(q, k, v, lengths, *, window: int = 0,
                                logit_cap: float = 0.0, is_global=None,
                                live=None):
    """q: (B, 1, Hq, D); k, v: (B, T, Hkv, D); lengths: int32 scalar or (B,)
    valid KV entries per row (current token included); live: optional (B,)
    bool row mask -> (B, 1, Hq, D)."""
    B, _, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    groups = Hq // Hkv
    lengths = jnp.broadcast_to(jnp.asarray(lengths), (B,))
    kexp = jnp.repeat(k, groups, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q[:, 0], kexp,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    pos = jnp.arange(T)
    mask = pos[None, None, :] < lengths[:, None, None]
    if window:
        w_ok = pos[None, None, :] > (lengths[:, None, None] - 1 - window)
        if is_global is not None:
            w_ok = w_ok | is_global
        mask = mask & w_ok
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    vexp = jnp.repeat(v, groups, axis=2)
    out = jnp.einsum("bht,bthd->bhd", p.astype(v.dtype), vexp,
                     preferred_element_type=jnp.float32)
    out = out[:, None].astype(q.dtype)
    if live is not None:
        out = jnp.where(live[:, None, None, None], out,
                        jnp.zeros_like(out))
    return out
