"""Gradient compression for cross-pod data parallelism.

Within a pod, XLA's automatic reduce-scatter over the data axis rides the
fast ICI.  Across pods (the 'pod' mesh axis; DCI/optical links at multi-pod
scale) gradient volume dominates, so the trainer can reduce the pod axis
*explicitly* under shard_map with int8-quantized summands (per-tensor scale,
stochastic-free symmetric quantization) + error feedback, cutting cross-pod
bytes 4x vs fp32 / 2x vs bf16.

`compressed_psum` is the wire primitive; `ErrorFeedback` keeps the
quantization residual so the compression is unbiased over time (Seide et al.
1-bit SGD lineage).  Both are mesh-agnostic and unit-tested on a host-device
mesh.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean-reduce over `axis_name` with int8 on-wire payload.

    The shards first agree on a GLOBAL scale (one scalar pmax — summing
    int8 values quantized under different per-shard scales would be
    meaningless), then quantize, then psum in int32 (exact).  The only loss
    is the shared-scale rounding, bounded by scale/2 per element (and
    absorbed by error feedback at the caller)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (qsum.astype(jnp.float32) * scale / n).astype(x.dtype)


class ErrorFeedback:
    """Residual-carrying compression: compress(g + e), e' = input - decoded."""

    @staticmethod
    def init(params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)

    @staticmethod
    def apply(grads: PyTree, residual: PyTree,
              axis_name: str) -> Tuple[PyTree, PyTree]:
        def one(g, e):
            x = g.astype(jnp.float32) + e.astype(jnp.float32)
            amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
            scale = jnp.maximum(amax / 127.0, 1e-12)
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            decoded = dequantize_int8(q, scale)
            new_e = (x - decoded).astype(jnp.bfloat16)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            return (qsum.astype(jnp.float32) * scale / n).astype(g.dtype), new_e

        out = jax.tree.map(one, grads, residual)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        g = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        e = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return g, e
