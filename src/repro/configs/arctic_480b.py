"""arctic-480b — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864, vocab=32000; MoE 128 experts top-2
**in parallel with a dense residual FFN** (Arctic's dense+MoE hybrid: the MoE
branch is added residually alongside a dense MLP).  ~480B total / ~17B active.
Optimizer: factored second moment (adafactor) — see DESIGN.md §6.4; a full
fp32 AdamW state for 480B params does not fit 256 v5e chips.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    attn_type="full",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_residual=True,
        dense_residual_d_ff=4864,
        capacity_factor=1.25,
    ),
    act="silu",
    glu=True,
    optimizer="adafactor",
    param_dtype="bfloat16",   # 477B fp32 master weights exceed 256x16GiB HBM
)

REDUCED = ModelConfig(
    name="arctic-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    attn_type="full",
    moe=MoEConfig(
        num_experts=4,
        top_k=2,
        expert_d_ff=96,
        dense_residual=True,
        dense_residual_d_ff=96,
        # E/top_k => capacity == group length: no token drops, so decode
        # exactly matches prefill in consistency tests.
        capacity_factor=2.0,
    ),
    act="silu",
    glu=True,
)
