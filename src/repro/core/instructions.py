"""FILCO instruction set (paper §2.5, Table 1).

Each function unit in the data plane decodes its own instruction stream; an
instruction is a few bytes — decoding one *is* the runtime reconfiguration
(no bitstream reload / recompile).  We keep the exact field lists of Table 1
and add binary encode/decode (fixed-width little-endian words) so streams can
be written to files, diffed, and replayed by the functional simulator.

Function units:
  InstrGen  — loads the stream header, dispatches to destination units
  IOMLoad   — DDR -> FMU transfer (submatrix window of an (M, N) operand)
  IOMStore  — FMU -> DDR transfer
  FMUInstr  — ping/pong op, src/des CU routing, 1-D-addressed window
  CUInstr   — compute op: consume operand streams from FMUs, emit result
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Iterable, List, Sequence, Tuple, Union

# unit ids for des_unit routing
UNIT_IOM_LOAD = 0
UNIT_IOM_STORE = 1
UNIT_FMU = 2
UNIT_CU = 3

# FMU/CU micro-ops
OP_NOP = 0
OP_RECV_IOM = 1      # FMU: receive `count` elements from IO manager
OP_SEND_CU = 2       # FMU: send the (row/col) window to des_cu
OP_RECV_CU = 3       # FMU: receive result elements from src_cu
OP_MM = 1            # CU: flexible matmul (loop bounds from count/rows/cols)


@dataclasses.dataclass(frozen=True)
class InstrGen:
    is_last: bool
    des_unit: int         # which function unit this block targets
    valid_length: int     # number of valid instructions in the block

    _FMT = "<BBH"

    def encode(self) -> bytes:
        return struct.pack(self._FMT, self.is_last, self.des_unit,
                           self.valid_length)

    @classmethod
    def decode(cls, b: bytes) -> "InstrGen":
        a, d, v = struct.unpack(cls._FMT, b)
        return cls(bool(a), d, v)


@dataclasses.dataclass(frozen=True)
class IOMLoad:
    is_last: bool
    ddr_addr: int
    des_fmu: int
    m: int                # full operand rows in DDR
    n: int                # full operand cols in DDR
    start_row: int
    end_row: int
    start_col: int
    end_col: int

    _FMT = "<BQHIIIIII"

    def encode(self) -> bytes:
        return struct.pack(self._FMT, self.is_last, self.ddr_addr,
                           self.des_fmu, self.m, self.n, self.start_row,
                           self.end_row, self.start_col, self.end_col)

    @classmethod
    def decode(cls, b: bytes) -> "IOMLoad":
        f = struct.unpack(cls._FMT, b)
        return cls(bool(f[0]), *f[1:])


@dataclasses.dataclass(frozen=True)
class IOMStore:
    is_last: bool
    ddr_addr: int
    src_fmu: int
    m: int
    n: int
    start_row: int
    end_row: int
    start_col: int
    end_col: int

    _FMT = "<BQHIIIIII"

    def encode(self) -> bytes:
        return struct.pack(self._FMT, self.is_last, self.ddr_addr,
                           self.src_fmu, self.m, self.n, self.start_row,
                           self.end_row, self.start_col, self.end_col)

    @classmethod
    def decode(cls, b: bytes) -> "IOMStore":
        f = struct.unpack(cls._FMT, b)
        return cls(bool(f[0]), *f[1:])


@dataclasses.dataclass(frozen=True)
class FMUInstr:
    is_last: bool
    ping_op: int          # op for the ping buffer this cycle
    pong_op: int          # op for the pong buffer this cycle
    src_cu: int
    des_cu: int
    count: int            # elements to receive (OP_RECV_*)
    start_row: int        # 1-D-addressed 2-D window (OP_SEND_CU) — the
    end_row: int          #   flexible memory *view* (paper §2.3)
    start_col: int
    end_col: int
    view_cols: int = 0    # row stride of the current view (FMV runtime shape)

    _FMT = "<BBBHHIIIIII"

    def encode(self) -> bytes:
        return struct.pack(self._FMT, self.is_last, self.ping_op,
                           self.pong_op, self.src_cu, self.des_cu, self.count,
                           self.start_row, self.end_row, self.start_col,
                           self.end_col, self.view_cols)

    @classmethod
    def decode(cls, b: bytes) -> "FMUInstr":
        f = struct.unpack(cls._FMT, b)
        return cls(bool(f[0]), *f[1:])


@dataclasses.dataclass(frozen=True)
class CUInstr:
    is_last: bool
    ping_op: int
    pong_op: int
    src_fmu: int          # operand-A FMU
    des_fmu: int          # result FMU
    count: int            # packed runtime loop bounds (m,k,n atoms) — the
                          #   flexible-parallelism instruction (paper §2.2)
    src_fmu_b: int = 0    # operand-B FMU (FILCO routes both operands)

    _FMT = "<BBBHHIH"

    def encode(self) -> bytes:
        return struct.pack(self._FMT, self.is_last, self.ping_op,
                           self.pong_op, self.src_fmu, self.des_fmu,
                           self.count, self.src_fmu_b)

    @classmethod
    def decode(cls, b: bytes) -> "CUInstr":
        f = struct.unpack(cls._FMT, b)
        return cls(bool(f[0]), *f[1:])


Instr = Union[InstrGen, IOMLoad, IOMStore, FMUInstr, CUInstr]

_DECODERS = {
    "gen": InstrGen, "iom_load": IOMLoad, "iom_store": IOMStore,
    "fmu": FMUInstr, "cu": CUInstr,
}


def pack_mkn(m_atoms: int, k_atoms: int, n_atoms: int) -> int:
    """Pack runtime loop bounds into the CU `count` field (10 bits each)."""
    assert 0 <= m_atoms < 1024 and 0 <= k_atoms < 1024 and 0 <= n_atoms < 1024
    return (m_atoms << 20) | (k_atoms << 10) | n_atoms


def unpack_mkn(count: int) -> Tuple[int, int, int]:
    return (count >> 20) & 1023, (count >> 10) & 1023, count & 1023


def encode_stream(instrs: Sequence[Instr]) -> bytes:
    """Encode a homogeneous instruction stream (one function unit)."""
    return b"".join(i.encode() for i in instrs)


def decode_stream(kind: str, data: bytes) -> List[Instr]:
    cls = _DECODERS[kind]
    size = struct.calcsize(cls._FMT)
    assert len(data) % size == 0, (kind, len(data), size)
    out = []
    for off in range(0, len(data), size):
        out.append(cls.decode(data[off: off + size]))
    return out


def stream_bytes(instrs: Iterable[Instr]) -> int:
    return sum(len(i.encode()) for i in instrs)
