"""Real-time recomposition controller — the serving-side face of FILCO's
"reconfigured in real-time and flexibly composed into a unified or multiple
independent accelerators" (paper §1, §2.1).

A :class:`ComposedServer` owns the full device mesh.  Each tenant runs the
engine of its *workload class* (transformer decode / SSM recurrent decode /
encoder embedding / enc-dec encode→decode — :mod:`repro.workloads`) on a
:class:`~repro.core.composer.MeshComposer` sub-accelerator, tensor-parallel
over its sub-mesh's model axis (``serve_engine_rules``), so a tenant's
measured throughput actually tracks the CUs it holds.  A tenant's engine is
really a :class:`ReplicaGroup` — ``dp`` independent same-design engine
replicas tiling the grant (the DesignPoint ``dp`` axis), so a memory-capped
small-model tenant on a wide grant batches in parallel across tiles instead
of sharding an unchanged batch.  Between decode steps
the controller samples per-tenant load (queue depth, owed work, arena
pressure) and asks a policy — by default the analytical model driving the
DSE Stage-2 search, pricing each tenant by its class's bound resource — for
a new CU split.  When the predicted gain clears the
hysteresis threshold it *live-recomposes*: the affected tenants' params and
pooled decode caches are reshard (sharded→sharded device_put) onto their new
sub-meshes while unaffected tenants keep their exact devices (delta
recomposition).

Reconfiguration cost is attacked on both ends, mirroring the paper's
real-time story: state migration is a ~10 ms device_put, and the dominant
post-recomposition XLA recompile (0.7-2.3 s measured cold) is hoisted off
the serving path by pre-compiling the target composition's decode/prefill
executables *before* the switch commits (``warm_compile``), optionally in a
background thread (``prewarm_async``) so compilation overlaps serving.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import itertools
import math
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from repro.common.platform import TPU_V5E, PlatformProfile
from repro.configs import get_config, get_reduced
from repro.configs.base import ModelConfig
from repro.core.analytical import (AccelConfig, decode_kv_read_latency,
                                   layer_latency, ssm_step_latency)
from repro.core.arena import PagedArena
from repro.core.composer import MeshComposer
from repro.core.dse import DesignPoint
from repro.distribution import partitioning as part
from repro.models import build_model
from repro.models.ssm import dims as ssm_dims
from repro.obs import MetricsRegistry, PredictionLedger, Telemetry
from repro.serve.dse import Stage1Optimizer, TenantDesignSpace, design_key
from repro.workloads import (DECODE, ENCDEC, ENCODER, SSM, DecodeEngine,
                             Engine, ExecutableCache, ServeConfig,
                             build_engine, workload_class_of)
from repro.workloads.decode import _mesh_of


def serve_engine_rules() -> part.ShardingRules:
    """serve_rules() tuned for the decode engine's composed sub-meshes.

    Two deltas vs the static-analysis serving rules: the KV cache shards
    over kv *heads* rather than split-K sequence (a dynamic-position scatter
    into a sequence-sharded cache forces SPMD to rematerialize the whole
    cache every step), and head counts that don't divide a given sub-mesh
    fall back to replication per-leaf at reshard time (fit_spec), so the
    same rules serve a 1-CU and an 8-CU composition.
    """
    rules = dict(part.serve_rules().rules)
    rules["kv_seq"] = None
    rules["kv_heads"] = "model"
    return part.ShardingRules(rules=rules)


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Per-tenant latency targets, milliseconds (0 = that target is
    untracked).  Drives two things in :class:`ComposedServer`:

    * the SLO-aware scheduler: a tenant whose head-of-line queue wait is
      burning its p99 TTFT budget (or whose observed per-token p99 has
      breached target) gets one of its slackest live streams preempted —
      exact device-state save to host — so the freed slot/pages admit the
      waiting request *this* step;
    * :meth:`ComposedServer.slo_attainment`: the fraction of observed
      TTFTs / per-token latencies under each target, read from the same
      ``obs`` histograms the fabric already collects.

    See docs/scheduling.md for the admission/preemption policy.
    """

    ttft_p50_ms: float = 0.0
    ttft_p99_ms: float = 0.0
    per_token_p50_ms: float = 0.0
    per_token_p99_ms: float = 0.0

    def tracked(self) -> bool:
        return any(v > 0 for v in (self.ttft_p50_ms, self.ttft_p99_ms,
                                   self.per_token_p50_ms,
                                   self.per_token_p99_ms))


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant model co-resident on the fabric."""

    name: str
    arch: str                        # architecture registry id
    reduced: bool = True
    serve: ServeConfig = ServeConfig()
    seed: int = 0
    # workload class: "auto" derives from the arch (attention-free SSM ->
    # "ssm", enc-dec with cross-attention -> "encdec", else "decode");
    # "encoder" is an explicit tenant choice — any arch can serve
    # prefill-only/embedding traffic
    workload: str = "auto"
    # ceiling on the tenant's data-parallel replica count (Stage-1 dp axis);
    # 1 pins the tenant to a single engine per grant
    dp_cap: int = 64
    # latency targets for the SLO-aware scheduler; None = best-effort
    # tenant (never preempted on latency grounds, absent from attainment)
    slo: Optional[SLOTarget] = None


@dataclasses.dataclass(frozen=True)
class TenantLoad:
    """Observed load signals only (the PR-5 ``decide`` input; superseded by
    :class:`TenantObservation`, which folds in the side-channel keywords)."""

    pending_tokens: int              # decode steps of work owed
    queue_depth: int                 # requests awaiting admission
    active: int                      # live decode slots
    arena_utilization: float         # KV arena pressure, 0..1


@dataclasses.dataclass(frozen=True)
class TenantObservation:
    """Everything the policy needs to know about one tenant, in one record.

    Built by the fabric each decide tick (:meth:`ComposedServer.observe`)
    and passed as ``decide(observations={tenant: TenantObservation(...)})``.
    """

    # load signals (sampled from the tenant's engine / replica group)
    pending_tokens: int = 0          # owed work units (steps / prompt toks)
    queue_depth: int = 0             # requests awaiting admission
    active: int = 0                  # live decode slots (all replicas)
    arena_utilization: float = 0.0   # KV-arena pressure, 0..1
    # workload identity + observed traffic (Stage-1 inputs)
    wclass: Optional[str] = None     # workload class (None: derive from cfg)
    recent_lengths: Tuple[int, ...] = ()   # recently observed job lengths
    src_len: int = 0                 # enc-dec per-slot source capacity
    space: Optional[TenantDesignSpace] = None   # Stage-1 search bounds


@dataclasses.dataclass(frozen=True)
class RecompositionEvent:
    """One applied recomposition, for logs/benchmarks."""

    step: int
    sizes_before: Dict[str, int]
    sizes_after: Dict[str, int]
    moved: Tuple[str, ...]
    unchanged: Tuple[str, ...]
    parked: Tuple[str, ...]
    seconds: float                   # state migration (device_put) only
    reason: str
    # tenants whose CU set did not move but whose engine design point
    # (TP degree / slots / bucket ladder) was reconfigured live, and the
    # per-tenant knobs actually applied (DSE Stage-1 deltas)
    retuned: Tuple[str, ...] = ()
    design: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    # moved tenant -> wall time of its first step on the new composition;
    # with a cold executable cache this is where the XLA recompile stall
    # lands — filled in by ComposedServer.step()
    post_step_seconds: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # ahead-of-time compilation performed before the switch committed
    warm_compile_seconds: float = 0.0
    warm_builds: int = 0             # cold executables compiled while warming
    overlapped: bool = False         # warmed in the background thread


# ---------------------------------------------------------------------------
# policy: Stage-2-style split search on the analytical model
# ---------------------------------------------------------------------------

# tile of sequence tokens used to price encoder (full-sequence MM) work in
# its compute-bound regime; the per-token cost is normalized back out
ENC_COST_TILE = 128


def _composed_total_s(lb, cus: int) -> float:
    """Latency of an MM layer on a composed TPU sub-accelerator.

    ``layer_latency`` models the board, where every CU shares one DDR — its
    DDR/stream terms are flat in CU count.  On the TPU fabric each CU is a
    mesh column with its own HBM and VMEM, so bandwidth scales with the
    grant; all workload classes must be priced on that same assumption
    (``ssm_step_latency`` already divides by CUs) or the split search
    compares classes on inconsistent rooflines.  Compute is already divided
    by CUs inside ``layer_latency``."""
    c = max(cus, 1)
    return max(lb.compute_s, lb.ddr_s / c, lb.stream_s / c) + lb.launch_s


class AnalyticalPolicy:
    """The serving-side DSE Stage 2: chooses a *composition of design
    points* by pricing each tenant on candidate sub-accelerator grants with
    the analytical latency model (the same machinery the offline DSE
    schedules with, §3.1) and minimizing the predicted makespan of the owed
    work.

    Two-stage (default): for every candidate CU grant ``c`` the per-tenant
    Stage-1 optimizer (:class:`~repro.serve.dse.Stage1Optimizer`) first
    picks that tenant's best engine configuration — TP degree over the
    sub-mesh, slot count, bucket ladder — and ``decide`` searches splits
    over those Stage-1-optimal :class:`~repro.core.dse.DesignPoint` memos,
    returning per-tenant design points (CUs + knobs) for the fabric to
    apply live.  With ``two_stage=False`` (the split-only ablation, and the
    behavior when the fabric supplies no design spaces) the CU count is the
    whole design point — exactly the pre-DSE policy.

    Class-aware costing (the heterogeneous-workload point): each tenant is
    priced by its workload class's actual bound resource —

    * ``decode``  — bandwidth-bound batched GEMV per decode step (weights
      streamed every token);
    * ``ssm``     — state-bandwidth-bound recurrent update per step
      (``ssm_step_latency``: params + read/write of the O(1) state);
    * ``encoder`` — compute-bound full-sequence MMs per owed prompt token;
    * ``encdec``  — decode-side batched GEMVs (self-attn, cross-attn and
      MLP projections) plus the per-step cross-attention source-cache read,
      whose bytes scale with the tenant's source length (``src_len``).

    So a compute-starved encoder tenant and a bandwidth-starved decode
    tenant are priced on different rooflines, and the split search allocates
    CUs by where they actually buy throughput instead of a one-size
    decode-GEMM model.

    Hysteresis: a new split is only worth a live recomposition when the
    predicted speedup clears ``min_gain`` — resharding has a real cost
    (device_put + one warm compile per new composition).  After every
    ``decide`` the policy exposes ``runner_up``: the best candidate split it
    did NOT return (the hysteresis-rejected best, or the second-best when a
    switch was returned) — the fabric speculatively prewarms it during idle
    decide intervals.
    """

    def __init__(self, platform: PlatformProfile = TPU_V5E,
                 min_gain: float = 1.25, two_stage: bool = True):
        self.platform = platform
        self.min_gain = min_gain
        self._cost_cache: Dict[Tuple, float] = {}
        self.runner_up: Optional[Dict[str, DesignPoint]] = None
        # Stage 1 shares this policy's step_cost memo as its price table
        self.stage1: Optional[Stage1Optimizer] = (
            Stage1Optimizer(self.step_cost, platform) if two_stage else None)
        # last non-idle decision's predicted makespans (telemetry /
        # benchmark): {"best_s": ..., "current_s": ...}
        self.predicted: Optional[Dict[str, float]] = None

    # -- per-tenant per-step cost on a c-CU sub-accelerator ----------------
    def step_cost(self, cfg: ModelConfig, batch: int, cus: int,
                  wclass: str = DECODE, src_len: int = 0,
                  kv_len: int = 0) -> float:
        """Predicted seconds per unit of owed work for one tenant on a
        ``cus``-CU sub-accelerator: per decode step for decode/ssm/encdec
        tenants, per owed prompt token for encoder tenants.

        src_len: enc-dec tenants' per-slot source length (frames read by
        every cross-attention step); ignored for other classes.

        kv_len: decoder-KV length each decode step streams per slot — the
        full per-slot capacity on the padded path, the expected live prefix
        under the ragged decode kernels (Stage 1 passes the estimate; 0
        keeps the term out, the pre-kernel pricing).  Attention archs only.
        """
        if cus <= 0:
            return float("inf")
        # the key carries the workload class: an SSM/encoder/encdec tenant
        # sharing a cfg.name with a transformer tenant must never read a
        # stale decode-GEMM price (and full/reduced configs share a name:
        # key on the priced dims too — d_ff and the KV dims are priced, so
        # they are in the key).  src_len prices the encdec cross-attention
        # read and kv_len the decoder-KV read, so both are in the key.
        kv = kv_len if wclass in (DECODE, ENCDEC) else 0
        key = (wclass, cfg.name, cfg.num_layers, cfg.d_model,
               cfg.d_ff, cfg.num_kv_heads, cfg.resolved_head_dim,
               max(batch, 1), cus, src_len if wclass == ENCDEC else 0, kv)
        if key not in self._cost_cache:
            accel = AccelConfig(
                name=f"tpu-sub{cus}", num_cus=cus,
                aies_per_cu=self.platform.num_compute_units,
                onchip_elems=cus * (self.platform.onchip_bytes // 4),
                num_fmus=max(cus, 1), fp=True, fmv=True, fmf=True)
            d = cfg.d_model
            if wclass == SSM and cfg.ssm is not None:
                # recurrent decode: state + parameter bandwidth per step
                d_in, dt_rank, n, w = ssm_dims(cfg)
                cost = cfg.num_layers * ssm_step_latency(
                    accel, self.platform, max(batch, 1), d, d_in, n, w,
                    dt_rank)
            elif wclass == ENCODER:
                # prefill-only: compute-bound full-sequence MMs, priced per
                # owed prompt token (demand for encoder tenants is queued
                # prompt tokens, not decode steps)
                layers = cfg.encoder_layers or cfg.num_layers
                lb_attn = layer_latency(accel, self.platform,
                                        ENC_COST_TILE, d, d)
                lb_mlp = layer_latency(accel, self.platform,
                                       ENC_COST_TILE, d, cfg.d_ff or 4 * d)
                cost = layers * (2 * _composed_total_s(lb_attn, cus)
                                 + 2 * _composed_total_s(lb_mlp, cus)) \
                    / ENC_COST_TILE
            elif wclass == ENCDEC:
                # enc-dec decode step: the decoder-side batched GEMVs — one
                # extra (d x d) projection pair vs plain decode for the
                # cross-attention block — plus the per-step cross-attention
                # source-cache read: 2·kv_heads·head_dim·src_len K/V
                # elements per layer per live slot, pure HBM bandwidth on
                # the composed sub-accelerator (each CU owns its HBM slice,
                # so the read scales down with the grant like every other
                # bandwidth term)
                b = max(batch, 1)
                lb_attn = layer_latency(accel, self.platform, b, d, d)
                lb_mlp = layer_latency(accel, self.platform,
                                       b, d, cfg.d_ff or 4 * d)
                cross_read_s = decode_kv_read_latency(
                    accel, self.platform, b, cfg.num_kv_heads,
                    cfg.resolved_head_dim, max(src_len, 1))
                kv_read_s = decode_kv_read_latency(
                    accel, self.platform, b, cfg.num_kv_heads,
                    cfg.resolved_head_dim, kv)
                cost = cfg.num_layers * (
                    3 * _composed_total_s(lb_attn, cus)
                    + 2 * _composed_total_s(lb_mlp, cus)
                    + cross_read_s + kv_read_s)
            else:
                # dominant decode GEMMs per layer: attention out/in (d x d)
                # and the MLP pair (d x d_ff), batched over live slots —
                # plus the per-step decoder-KV stream when the caller
                # prices it (kv_len > 0)
                lb_attn = layer_latency(accel, self.platform,
                                        max(batch, 1), d, d)
                lb_mlp = layer_latency(accel, self.platform,
                                       max(batch, 1), d, cfg.d_ff or 4 * d)
                kv_read_s = decode_kv_read_latency(
                    accel, self.platform, max(batch, 1), cfg.num_kv_heads,
                    cfg.resolved_head_dim, kv)
                cost = cfg.num_layers * (
                    2 * _composed_total_s(lb_attn, cus)
                    + 2 * _composed_total_s(lb_mlp, cus)
                    + kv_read_s)
            self._cost_cache[key] = cost
        return self._cost_cache[key]

    # -- the two-stage search ----------------------------------------------
    def decide(self, observations: Mapping[str, TenantObservation],
               cfgs: Mapping[str, ModelConfig],
               current: Mapping[str, object],
               num_cus: int,
               ) -> Tuple[Dict[str, DesignPoint], str]:
        """Return (per-tenant design points, reason).

        Each returned :class:`DesignPoint` carries the tenant's CU grant
        plus its Stage-1-optimal engine knobs (TP degree / replica count /
        slots / bucket ladder — ``None`` knobs mean "keep").  Tenants with
        no load are parked (cus 0); returning the ``current`` points means
        "leave the fabric alone".

        ``observations`` maps tenant -> :class:`TenantObservation`: the
        sampled load signals plus workload class (``None`` derives from the
        tenant's config; encoder tenancy can't be derived, so mixed fabrics
        set it), enc-dec source capacity (prices the per-step
        cross-attention read), recently observed job lengths and the
        tenant's Stage-1 design space — without a space a tenant is priced
        split-only (its CU count is the whole design point).  ``current``
        maps tenant -> applied CU count (int) or applied DesignPoint."""
        loads = dict(observations)
        classes = {t: o.wclass for t, o in loads.items()
                   if o.wclass is not None}
        src_lens = {t: o.src_len for t, o in loads.items() if o.src_len}
        lengths = {t: o.recent_lengths for t, o in loads.items()}
        spaces = {t: o.space for t, o in loads.items()
                  if o.space is not None}
        for t in cfgs:
            classes.setdefault(t, workload_class_of(cfgs[t]))
        # arena pressure inflates demand: a hot arena means queued work the
        # pending-token count can't see yet
        demand = {t: ld.pending_tokens * (1.0 + ld.arena_utilization)
                  for t, ld in loads.items()}
        busy = [t for t, d in demand.items() if d > 0]

        def concurrency(t: str) -> int:
            return max(loads[t].active + loads[t].queue_depth, 1)

        def split_only_cost(t: str, c: int) -> float:
            if c <= 0:
                return float("inf")
            cost = self.step_cost(cfgs[t], loads[t].active or 1, c,
                                  classes[t], src_len=src_lens.get(t, 0))
            if self.stage1 is not None and spaces:
                # a space-less tenant in a two-stage decide must price in
                # Stage 1's units (seconds per TOKEN: one batched step
                # emits `active` tokens) or the makespan would compare
                # per-step against per-token costs and systematically
                # over-grant the space-less tenant
                cost /= max(loads[t].active, 1)
            return cost

        def stage1_point(t: str, c: int) -> DesignPoint:
            """Stage 1: the tenant's best design point on a c-CU grant."""
            sp = spaces.get(t)
            if self.stage1 is not None and sp is not None:
                return self.stage1.best(cfgs[t], sp, concurrency(t), c,
                                        lengths.get(t, ()),
                                        src_lens.get(t, 0))
            return DesignPoint(cus=max(c, 0), cost=split_only_cost(t, c))

        def as_point(t: str, v) -> DesignPoint:
            """Normalize a ``current`` entry and (re-)price it under the
            current load — the hysteresis baseline."""
            if not isinstance(v, DesignPoint):
                return stage1_point(t, int(v))
            sp = spaces.get(t)
            if self.stage1 is not None and sp is not None and v.cus > 0:
                cost = self.stage1.cost_of(cfgs[t], sp, concurrency(t), v,
                                           lengths.get(t, ()),
                                           src_lens.get(t, 0))
            else:
                cost = split_only_cost(t, v.cus)
            return dataclasses.replace(v, cost=cost)

        cur_points = {t: as_point(t, v) for t, v in current.items()}
        if not busy:
            self.runner_up = None
            self.predicted = None
            return dict(cur_points), "idle"

        # Stage-1 memo: one design-point search per (busy tenant, grant)
        memo: Dict[Tuple[str, int], DesignPoint] = {}

        def point(t: str, c: int) -> DesignPoint:
            if (t, c) not in memo:
                memo[(t, c)] = stage1_point(t, c)
            return memo[(t, c)]

        def makespan(points: Mapping[str, DesignPoint]) -> float:
            worst = 0.0
            for t in busy:
                p = points.get(t)
                cost = p.cost if p is not None else float("inf")
                worst = max(worst, demand[t] * cost)
            return worst

        # Stage 2: split search over Stage-1-optimal design points
        best_pts, best_cost = None, float("inf")
        second_pts, second_cost = None, float("inf")
        for split in _candidate_splits(num_cus, busy, demand):
            pts = {t: point(t, c) for t, c in zip(busy, split)}
            cost = makespan(pts)
            if cost < best_cost:
                second_pts, second_cost = best_pts, best_cost
                best_pts, best_cost = pts, cost
            elif cost < second_cost:
                second_pts, second_cost = pts, cost
        assert best_pts is not None

        cur_cost = makespan(cur_points)
        # JSON-safe telemetry: an admit tick's current makespan is infinite
        # (a parked tenant owes work) — record None, not float('inf')
        self.predicted = {
            "best_s": best_cost,
            "current_s": cur_cost if cur_cost != float("inf") else None}
        if cur_cost == float("inf"):
            self.runner_up = second_pts
            return best_pts, "admit"            # a parked tenant got work
        if cur_cost / max(best_cost, 1e-12) >= self.min_gain:
            self.runner_up = second_pts
            if self._sizes(best_pts) == self._sizes(cur_points):
                # same split, better per-tenant configs: a pure Stage-1
                # delta (slots / TP / ladder) applied with no CU move
                return best_pts, "retune"
            if len(busy) == 1:
                return best_pts, "unify"
            return best_pts, "rebalance"
        # staying put: the best candidate is what we'd switch to next —
        # that's the design worth prewarming while the fabric idles
        self.runner_up = (best_pts
                          if self._sizes(best_pts) != self._sizes(cur_points)
                          else second_pts)
        return dict(cur_points), "hysteresis"

    @staticmethod
    def _sizes(points: Mapping[str, DesignPoint]) -> Dict[str, int]:
        return {t: p.cus for t, p in points.items() if p.cus > 0}


def _compositions(total: int, parts: int):
    """All ways to write ``total`` as ``parts`` positive integers."""
    if parts == 1:
        yield (total,)
        return
    for cuts in itertools.combinations(range(1, total), parts - 1):
        prev, out = 0, []
        for c in cuts:
            out.append(c - prev)
            prev = c
        out.append(total - prev)
        yield tuple(out)


# exhaustive Stage-2-style enumeration is C(num_cus-1, tenants-1): fine on a
# board-scale fabric, explosive on a pod.  Past this budget, fall back to a
# demand-proportional water-filling split (the argmax of the monotone
# makespan model in the common case, computed in O(cus x tenants)).
MAX_ENUMERATED_SPLITS = 20_000


def _candidate_splits(num_cus: int, busy: Sequence[str],
                      demand: Mapping[str, float]):
    if math.comb(num_cus - 1, len(busy) - 1) <= MAX_ENUMERATED_SPLITS:
        yield from _compositions(num_cus, len(busy))
        return
    total = sum(demand[t] for t in busy)
    shares = [max(1, int(num_cus * demand[t] / total)) for t in busy]
    spare = num_cus - sum(shares)
    order = sorted(range(len(busy)), key=lambda i: -demand[busy[i]])
    i = 0
    while spare != 0:                    # hand leftovers to (or claw back
        j = order[i % len(order)]        # from) the most-loaded tenants
        step = 1 if spare > 0 else (-1 if shares[j] > 1 else 0)
        shares[j] += step
        spare -= step
        i += 1
    yield tuple(shares)


# ---------------------------------------------------------------------------
# data-parallel replica groups: N independent engines inside one grant
# ---------------------------------------------------------------------------

class _Replica:
    """One engine instance inside a :class:`ReplicaGroup`, plus its rid
    translation — engine rids are per-engine and restart on adoption, so
    the group owns the stable rid a caller sees (``to_group`` maps the
    engine's rid to it)."""

    __slots__ = ("engine", "to_group", "index", "obs")

    def __init__(self, engine: Engine, index: int = 0, obs=None):
        self.engine = engine
        self.to_group: Dict[int, int] = {}
        self.index = index
        # the Telemetry handle the engine records into: one registry per
        # replica (same labels), so the group can merge histograms across
        # replicas and harvest a retiring replica's registry on a dp shrink
        self.obs = obs


class ReplicaGroup:
    """``dp`` independent same-design engines tiling one tenant's CU grant
    (the DesignPoint ``dp`` axis — Herald-style replica tiling).

    One decode step's batched GEMV cannot use more slots than fit one
    replica's KV arena, so on a wide grant a memory-capped tenant is better
    served by N narrow engines on disjoint ``replica_submesh`` tiles, each
    decoding its own batch concurrently, than by one wide engine whose
    extra CUs shard an unchanged (memory-bound) batch.  The group IS the
    tenant's engine as far as the fabric is concerned — same Engine
    protocol — and owns:

    * **routing**: ``submit`` places each request on the least-loaded
      replica (fewest owed tokens, then shallowest queue, then lowest
      index — deterministic);
    * **merged load signals**: queue depth / active / owed tokens sum
      across replicas, arena pressure averages, ``recent_lengths`` is the
      union — so the policy observes the tenant, not a replica;
    * **the dp retune** (``apply`` with a changed ``point.dp``): retiring
      replicas are drained via :meth:`~DecodeEngine.evacuate` and their
      live requests adopted by survivors through exact cache-row copies
      (never re-prefilled — a different reduction order could flip an
      argmax), queues rebalance across the new replica set, and every
      request keeps its stable group rid, so per-request streams are
      bit-identical across the retune;
    * **warm compile across tiles**: every replica slice has its own mesh
      fingerprint, so ``warm_compile`` warms each of the ``dp`` slices
      through the shared executable cache (slices of equal width still
      share programs whenever their fingerprints coincide).

    Replicas at the same TP degree run identical XLA programs — the slices
    differ only in device ids — so which replica serves a request never
    changes its tokens (pinned by tests/test_fabric.py).
    """

    def __init__(self, wclass: str, model, params, serve_cfg: ServeConfig,
                 *, sub=None, rules: Optional[part.ShardingRules] = None,
                 exec_cache: Optional[ExecutableCache] = None,
                 cu_axis: str = "model", obs: Optional[Telemetry] = None):
        self._wclass = wclass
        self.workload_class = wclass
        self._model = model
        self._params = params            # annotated: grows fresh replicas
        self._serve_cfg = serve_cfg
        self._rules = rules
        self._exec = (exec_cache if exec_cache is not None
                      else ExecutableCache())
        self._cu_axis = cu_axis
        self._granted = _mesh_of(sub)    # the group's full grant (unsliced)
        self._dp = 1
        self._next_rid = 0
        # group-level telemetry: spans go to the shared tracer; each
        # replica's engine records into a *fresh* registry under the same
        # labels, merged on demand by metrics()
        self._obs = obs if obs is not None else Telemetry()
        # harvested from retired replicas so results()/telemetry survive a
        # dp shrink
        self._retired_results: Dict[int, Any] = {}
        self._retired_builds = 0
        self._retired_reshards = 0
        self._retired_preempts = 0
        self._retired_metrics = MetricsRegistry()
        rep_obs = self._obs.fresh()
        self._replicas: List[_Replica] = [_Replica(build_engine(
            wclass, model, params, serve_cfg, mesh=self._granted,
            rules=rules, exec_cache=self._exec, obs=rep_obs), obs=rep_obs)]

    # -- grant geometry -------------------------------------------------
    def _grant_width(self, granted) -> Optional[int]:
        if granted is None or self._cu_axis not in granted.axis_names:
            return None
        ax = granted.axis_names.index(self._cu_axis)
        return granted.devices.shape[ax]

    @property
    def dp(self) -> int:
        """Live replica count."""
        return self._dp

    @property
    def replicas(self) -> Tuple[Engine, ...]:
        """The member engines, replica index order (tests/telemetry)."""
        return tuple(r.engine for r in self._replicas)

    # -- work ingestion / progress --------------------------------------
    def submit(self, tokens, max_new_tokens: int = 16, **kwargs) -> int:
        """Route one request to the least-loaded replica (owed tokens,
        then queue depth, then replica index — deterministic tie-break);
        returns its stable group rid."""
        rep = min(self._replicas,
                  key=lambda r: (r.engine.pending_tokens(),
                                 r.engine.queue_depth, r.index))
        erid = rep.engine.submit(tokens, max_new_tokens, **kwargs)
        grid = self._next_rid
        self._next_rid += 1
        rep.to_group[erid] = grid
        return grid

    def step(self) -> List[Tuple[int, Any]]:
        """Step every replica; emitted (rid, unit) pairs carry group rids."""
        out: List[Tuple[int, Any]] = []
        for rep in self._replicas:
            out.extend((rep.to_group[erid], v) for erid, v in
                       rep.engine.step())
        return out

    def results(self) -> Dict[int, Any]:
        out = dict(self._retired_results)
        for rep in self._replicas:
            out.update((rep.to_group[erid], v) for erid, v in
                       rep.engine.results().items())
        return out

    def snapshot(self) -> Dict[int, Any]:
        out = dict(self._retired_results)
        for rep in self._replicas:
            out.update((rep.to_group[erid], v) for erid, v in
                       rep.engine.snapshot().items())
        return out

    def run_to_completion(self, max_steps: int = 1000) -> Dict[int, Any]:
        """Step until idle (or ``max_steps``); returns ``snapshot()``."""
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()
        return self.snapshot()

    # -- merged load signals --------------------------------------------
    @property
    def queue_depth(self) -> int:
        return sum(r.engine.queue_depth for r in self._replicas)

    @property
    def active_count(self) -> int:
        return sum(r.engine.active_count for r in self._replicas)

    @property
    def has_work(self) -> bool:
        return any(r.engine.has_work for r in self._replicas)

    def pending_tokens(self) -> int:
        return sum(r.engine.pending_tokens() for r in self._replicas)

    def arena_utilization(self) -> float:
        return (sum(r.engine.arena_utilization() for r in self._replicas)
                / max(len(self._replicas), 1))

    def recent_lengths(self) -> Tuple[int, ...]:
        return tuple(itertools.chain.from_iterable(
            r.engine.recent_lengths() for r in self._replicas))

    # -- preemption (the SLO scheduler's lever) --------------------------
    @property
    def preempted_depth(self) -> int:
        """Requests currently parked (preempted, awaiting re-admission)."""
        return sum(r.engine.preempted_depth for r in self._replicas)

    @property
    def preempt_count(self) -> int:
        return self._retired_preempts + sum(r.engine.preempt_count
                                            for r in self._replicas)

    def queue_head_wait_s(self, now: Optional[float] = None) -> float:
        """Longest head-of-line queue wait across replicas (seconds) —
        the TTFT burn the SLO scheduler compares against targets."""
        waits = [r.engine.queue_head_wait_s(now) for r in self._replicas
                 if r.engine.queue_depth > 0]
        return max(waits) if waits else 0.0

    def preempt_one(self) -> Optional[int]:
        """Preempt one live stream — exact device-state save, re-admitted
        later bit-identically — on the replica whose head-of-line request
        has waited longest (that is where a freed slot buys TTFT;
        replica-index tie-break keeps victim choice deterministic under
        equal waits).  Returns the victim's group rid, or None when no
        replica holds a preemptible stream."""
        order = sorted(
            self._replicas,
            key=lambda r: (-(r.engine.queue_head_wait_s()
                             if r.engine.queue_depth > 0 else 0.0),
                           r.index))
        for rep in order:
            erid = rep.engine.preempt_one()
            if erid is not None:
                return rep.to_group.get(erid, erid)
        return None

    # -- pass-throughs the fabric's DSE plumbing reads ------------------
    @property
    def cfg(self) -> ServeConfig:
        return self._replicas[0].engine.cfg

    @property
    def params(self):
        """Replica 0's device-resident params (tests/telemetry: replicas
        share one design, so one replica's placement is the tenant's)."""
        return self._replicas[0].engine.params

    @property
    def arena(self):
        """Replica 0's admission arena (slots are a per-replica knob, so
        per-slot sizing reads one replica); None for arena-less classes."""
        return getattr(self._replicas[0].engine, "arena", None)

    @property
    def _max_src(self) -> int:
        return getattr(self._replicas[0].engine, "_max_src", 0)

    # -- telemetry -------------------------------------------------------
    @property
    def reshard_count(self) -> int:
        return self._retired_reshards + sum(r.engine.reshard_count
                                            for r in self._replicas)

    @property
    def compile_builds(self) -> int:
        return self._retired_builds + sum(r.engine.compile_builds
                                          for r in self._replicas)

    def metrics(self) -> MetricsRegistry:
        """Merged view of every replica's metrics registry plus the
        registries harvested from replicas retired by dp shrinks.  All
        replicas record under identical labels into a shared fixed bucket
        layout, so the merge is element-wise and order-independent —
        quantiles of the merged histograms describe the *tenant*, not one
        replica."""
        merged = MetricsRegistry()
        merged.merge(self._retired_metrics)
        for rep in self._replicas:
            if rep.obs is not None:
                merged.merge(rep.obs.registry)
        return merged

    def latency_ms(self) -> Dict[str, Dict[str, float]]:
        """Merged-histogram latency summary (milliseconds) for the group's
        key per-step distributions — the compact ``stats()`` view of the
        full ``metrics()`` registry."""
        out: Dict[str, Dict[str, float]] = {}
        reg = self.metrics()
        for name in ("decode_step_s", "ttft_s", "queue_wait_s",
                     "prefill_s", "encode_s"):
            h = reg.merged_histogram(name)
            if h.count:
                out[name[:-2]] = {
                    "p50_ms": round(h.quantile(0.5) * 1e3, 4),
                    "p99_ms": round(h.quantile(0.99) * 1e3, 4),
                    "n": h.count,
                }
        return out

    def stats(self) -> Dict[str, Any]:
        """Group-merged snapshot (sums / averages across replicas), plus
        each replica's own ``stats()`` under ``per_replica``.

        A superset of one engine's ``stats()``: engine-specific keys the
        group doesn't know about (``bucket_hits``, ``seqs_done``, ...)
        pass through merged — numerics sum, dicts of numerics sum
        key-wise — so telemetry consumers see the tenant, not a wrapper.
        """
        per = [r.engine.stats() for r in self._replicas]
        merged: Dict[str, Any] = {}
        for key in per[0]:
            vals = [s[key] for s in per if key in s]
            head = vals[0]
            if isinstance(head, bool):
                merged[key] = head
            elif isinstance(head, (int, float)):
                merged[key] = type(head)(sum(vals))
            elif isinstance(head, dict) and all(
                    isinstance(v, (int, float)) and not isinstance(v, bool)
                    for d in vals for v in d.values()):
                tot: Dict[Any, Any] = {}
                for d in vals:
                    for k, v in d.items():
                        tot[k] = tot.get(k, 0) + v
                merged[key] = tot
            else:
                merged[key] = head       # replicas share one design
        merged.update({
            "workload_class": self.workload_class,
            "dp": self._dp,
            "queue_depth": self.queue_depth,
            "active": self.active_count,
            "pending_tokens": self.pending_tokens(),
            "arena_utilization": round(self.arena_utilization(), 4),
            "reshard_count": self.reshard_count,
            "compile_builds": self.compile_builds,
            "design": self.design(),
            "latency_ms": self.latency_ms(),
            "per_replica": per,
        })
        return merged

    # -- recomposition / design-point reconfiguration -------------------
    def design(self) -> Dict[str, Any]:
        """The group's applied design point: replica 0's engine knobs
        (replicas share one design) plus the replica count."""
        d = dict(self._replicas[0].engine.design())
        d["dp"] = self._dp
        return d

    def sync(self) -> None:
        for rep in self._replicas:
            rep.engine.sync()

    def reshard_to(self, sub) -> None:
        """Move the whole group onto a new grant, each replica onto its
        tile (current dp kept)."""
        self._granted = _mesh_of(sub)
        for rep in self._replicas:
            rep.engine.reshard_to(part.replica_submesh(
                self._granted, rep.index, self._dp, self._cu_axis))

    def apply(self, sub=None,
              point: Optional[DesignPoint] = None) -> Dict[str, Any]:
        """Apply a design-point delta group-wide (``None`` fields = keep).

        ``point.dp`` is consumed here: an unchanged dp fans the
        per-replica knobs out to every member engine (each on its
        ``replica_submesh`` tile of the — possibly new — grant); a changed
        dp runs the drain-and-rebalance retune (:meth:`_retarget_dp`),
        which preserves every request's stable rid and exact token stream.
        Returns the knobs actually applied (replica 0's view, plus ``dp``
        when it changed)."""
        point = point if point is not None else DesignPoint(cus=0)
        granted = _mesh_of(sub) if sub is not None else self._granted
        dp = point.dp if point.dp is not None else self._dp
        dp = max(int(dp), 1)
        width = self._grant_width(granted)
        if width is not None:
            dp = min(dp, width)
        eng_point = dataclasses.replace(point, dp=None)
        if dp != self._dp:
            applied = self._retarget_dp(granted, dp, eng_point)
            applied["dp"] = dp
        else:
            applied = {}
            for rep in self._replicas:
                s = (part.replica_submesh(granted, rep.index, dp,
                                          self._cu_axis)
                     if sub is not None else None)
                out = rep.engine.apply(s, eng_point)
                if rep.index == 0:
                    applied = out
        self._granted = granted
        return applied

    def _retarget_dp(self, granted, dp: int,
                     eng_point: DesignPoint) -> Dict[str, Any]:
        """Change the replica count live: drain, re-tile, rebalance.

        Retiring replicas are stripped of ALL work (live slots exported as
        exact host cache blocks, queues handed back) and their finished
        records / telemetry harvested; surviving replicas give up their
        queues too, then move onto their new ``replica_submesh`` tiles with
        their slot pools pre-grown to fit planned adoptions; growth
        replicas are built fresh on theirs.  Orphaned live requests are
        then adopted least-loaded-first via exact cache-row copies (bit-
        identical streams — never re-prefilled) and queues redistribute by
        the same order, every request keeping its stable group rid."""
        keep, retire = self._replicas[:dp], self._replicas[dp:]
        span_t0, span_src = time.perf_counter(), self._dp
        live: List[Tuple[int, Any, Any]] = []
        queued: List[Tuple[int, Any]] = []
        for rep in retire:
            l_reqs, q_reqs = rep.engine.evacuate()
            live.extend((rep.to_group[r.rid], r, blk) for r, blk in l_reqs)
            queued.extend((rep.to_group[r.rid], r) for r in q_reqs)
            for erid, v in rep.engine.results().items():
                if erid in rep.to_group:
                    self._retired_results[rep.to_group[erid]] = v
            self._retired_builds += rep.engine.compile_builds
            self._retired_reshards += rep.engine.reshard_count
            self._retired_preempts += rep.engine.preempt_count
            if rep.obs is not None:
                # histograms observed by the retiring replica stay in the
                # tenant's merged view (parallel to results/builds above)
                self._retired_metrics.merge(rep.obs.registry)
        for rep in keep:
            queued.extend((rep.to_group[r.rid], r)
                          for r in rep.engine.export_queued())
        # plan live adoptions before any engine moves: least-loaded target
        # first, replica-index tie-break (deterministic)
        occupancy = {i: (keep[i].engine.active_count if i < len(keep) else 0)
                     for i in range(dp)}
        placed: Dict[int, List] = {i: [] for i in range(dp)}
        for item in live:
            i = min(range(dp),
                    key=lambda j: (occupancy[j] + len(placed[j]), j))
            placed[i].append(item)
        applied: Dict[str, Any] = {}
        reps: List[_Replica] = []
        for i in range(dp):
            tile = part.replica_submesh(granted, i, dp, self._cu_axis)
            if i < len(keep):
                rep = keep[i]
                need = rep.engine.active_count + len(placed[i])
                slots = (eng_point.slots if eng_point.slots is not None
                         else rep.engine.design()["slots"])
                out = rep.engine.apply(tile, dataclasses.replace(
                    eng_point, slots=max(slots, need, 1)))
                if i == 0:
                    applied = out
            else:
                rep_obs = self._obs.fresh()
                rep = _Replica(self._build_replica(
                    tile, eng_point, min_slots=len(placed[i]), obs=rep_obs),
                    obs=rep_obs)
            rep.index = i
            reps.append(rep)
        self._replicas, self._dp = reps, dp
        for i, items in placed.items():
            rep = reps[i]
            for grid, req, block in items:
                rep.to_group[rep.engine.adopt_request(req, block)] = grid
        for grid, req in queued:
            rep = min(reps, key=lambda r: (r.engine.pending_tokens(),
                                           r.engine.queue_depth, r.index))
            rep.to_group[rep.engine.adopt_queued(req)] = grid
        if self._obs.enabled:
            self._obs.tracer.record(
                "dp_rebalance", span_t0, time.perf_counter(),
                {"src": span_src, "dst": dp, "moved": len(live),
                 "requeued": len(queued)})
        return applied

    def _build_replica(self, mesh, eng_point: DesignPoint,
                       min_slots: int = 0, obs: Optional[Telemetry] = None
                       ) -> Engine:
        """A fresh member engine on ``mesh`` at the group's design (dp
        grow) — sized to at least ``min_slots`` so planned adoptions fit."""
        d0 = self._replicas[0].engine.design()
        slots = (eng_point.slots if eng_point.slots is not None
                 else d0["slots"])
        cfg = dataclasses.replace(self._serve_cfg,
                                  max_slots=max(slots, min_slots, 1))
        ladder = (eng_point.buckets if eng_point.buckets is not None
                  else d0["buckets"])
        if ladder:
            cfg = dataclasses.replace(cfg, len_buckets=tuple(ladder))
        eng = build_engine(self._wclass, self._model, self._params, cfg,
                           mesh=mesh, rules=self._rules,
                           exec_cache=self._exec, obs=obs)
        tp = eng_point.tp if eng_point.tp is not None else d0["tp"]
        if tp is not None:
            eng.apply(None, DesignPoint(cus=0, tp=tp))
        return eng

    def warm_compile(self, sub,
                     point: Optional[DesignPoint] = None) -> int:
        """Pre-compile a candidate design point's programs for every
        replica tile of a candidate grant (``point.dp``, defaulting to the
        live dp), through the shared executable cache — each tile has its
        own mesh fingerprint, so warming replica 0's programs alone would
        leave the sibling tiles cold.  Returns cold builds performed."""
        point = point if point is not None else DesignPoint(cus=0)
        granted = _mesh_of(sub) if sub is not None else self._granted
        dp = point.dp if point.dp is not None else self._dp
        dp = max(int(dp), 1)
        width = self._grant_width(granted)
        if width is not None:
            dp = min(dp, width)
        eng_point = dataclasses.replace(point, dp=None)
        eng0 = self._replicas[0].engine
        if granted is None:
            return eng0.warm_compile(None, eng_point)
        return sum(eng0.warm_compile(
            part.replica_submesh(granted, i, dp, self._cu_axis), eng_point)
            for i in range(dp))


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class ComposedServer:
    """Multi-tenant serving on one composable fabric with live, delta
    recomposition between decode steps.

    Tenants are a *mixed fleet*: each runs the engine of its workload class
    (transformer decode / SSM recurrent decode / encoder embedding /
    enc-dec encode→decode — see ``repro.workloads``), and the policy prices
    each class by its bound resource.  All engines share one fabric-level AOT executable cache
    keyed by (config fingerprint, mesh fingerprint, shapes), so same-config
    tenants reuse each other's warm programs instead of compiling per
    engine.

    With a two-stage :class:`AnalyticalPolicy` (the default) the fabric
    runs the paper's full DSE in the serving loop: each decide tick it
    builds per-tenant :class:`TenantObservation` records (``observe``), the
    policy returns Stage-1-optimal design points per tenant (CUs + TP
    degree + replica count + slots + bucket ladder), and ``recompose``
    applies the deltas live — CU moves via ``reshard_to``-style migration,
    knob changes via ``Engine.apply`` (retunes; a changed ``dp`` triggers
    the ReplicaGroup's drain-and-rebalance), both re-entering the shared
    AOT cache under the new fingerprints so warm-compile covers the new
    programs.

    Each tenant's engine is a :class:`ReplicaGroup`: ``dp`` independent
    same-design engines tiling the tenant's grant, with requests routed to
    the least-loaded replica and load signals merged — at ``dp=1`` (the
    default) the group is a transparent wrapper over one engine.

    tp: shard each tenant's engine (params + pooled state) over its
        sub-mesh with ``serve_engine_rules`` so granted CUs buy measured
        tokens/s; off -> replicated engines (bit-identical resharding).
    warm: pre-compile a target composition's executables before committing
        a recomposition, so the first post-move step skips the XLA stall.
    prewarm_async: compile candidate compositions in a background thread
        while the old composition keeps serving; the switch commits on a
        later autoscale tick once the executables are ready.  Idle decide
        intervals additionally prewarm the policy's runner-up split
        speculatively, so the *next* plausible recomposition is warm too.
    """

    def __init__(self, mesh, tenants: Sequence[TenantSpec], *,
                 policy: Optional[AnalyticalPolicy] = None,
                 decide_every: int = 4, cu_axis: str = "model",
                 tp: bool = True, warm: bool = True,
                 prewarm_async: bool = False, telemetry: bool = True,
                 events_cap: int = 256, slo_preempt: bool = True):
        self.composer = MeshComposer(mesh, cu_axis=cu_axis)
        self.policy = policy
        self.decide_every = decide_every
        self.rules = serve_engine_rules() if tp else None
        self.warm = warm
        self.prewarm_async = prewarm_async
        self.specs = {t.name: t for t in tenants}
        # fabric-wide telemetry (repro.obs): one tracer for every span in
        # the stack, a fabric-level registry for step/SLO histograms, and
        # the predicted-vs-measured ledger.  telemetry=False swaps in a
        # disabled handle — every record call becomes a no-op; token
        # streams are bit-identical either way (pinned by tests/test_obs).
        self.obs = Telemetry() if telemetry else Telemetry.off()
        self.ledger = PredictionLedger()
        # recomposition history: bounded (a long-running fabric must not
        # grow per event) — stats() totals below survive eviction
        self.events: "collections.deque[RecompositionEvent]" = \
            collections.deque(maxlen=max(int(events_cap), 1))
        self._recompositions = 0
        self._retunes = 0
        self._recompose_seconds_total = 0.0
        self._warm_compile_seconds_total = 0.0
        self._stall_probe: Dict[str, RecompositionEvent] = {}
        self._step_no = 0
        self._tokens_emitted: Dict[str, int] = {t.name: 0 for t in tenants}
        # SLO-aware scheduler state: preemptions issued on latency grounds,
        # plus the per-tenant observed quantiles (ms) refreshed at decide
        # cadence — the per-step path must not merge histogram registries.
        # slo_preempt=False keeps attainment *reporting* while never
        # preempting (the slot-granular benchmark baseline arm).
        self.slo_preempt = slo_preempt
        self._slo_preemptions = 0
        self._slo_obs: Dict[Tuple[str, str], float] = {}
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._pending_prewarm: Optional[
            Tuple[Dict[str, DesignPoint], str, list]] = None
        # speculative runner-up prewarm bookkeeping
        self.speculative_prewarms = 0
        self._spec_warmed: set = set()
        self._spec_futures: List[concurrent.futures.Future] = []

        # initial composition: equal shares, remainder to the first tenants
        n = len(tenants)
        if n > self.composer.num_cus:
            raise ValueError(
                f"{n} tenants need at least {n} CUs; the fabric has "
                f"{self.composer.num_cus} (on CPU, fake more host devices "
                f"with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        base, extra = divmod(self.composer.num_cus, n)
        sizes = {t.name: base + (1 if i < extra else 0)
                 for i, t in enumerate(tenants)}
        self.subs, _ = self.composer.recompose({}, sizes)

        # fabric-level executable cache: shared across every tenant engine
        self.exec_cache = ExecutableCache(capacity=128)
        self.cfgs: Dict[str, ModelConfig] = {}
        self.classes: Dict[str, str] = {}
        self.src_lens: Dict[str, int] = {}
        self.engines: Dict[str, ReplicaGroup] = {}
        for spec in tenants:
            cfg = (get_reduced(spec.arch) if spec.reduced
                   else get_config(spec.arch))
            model = build_model(cfg)
            params = model.init(jax.random.key(spec.seed))  # annotated: TP
            wclass = (workload_class_of(cfg) if spec.workload == "auto"
                      else spec.workload)
            self.cfgs[spec.name] = cfg
            self.classes[spec.name] = wclass
            if wclass == ENCDEC:
                # prices the per-step cross-attention source-cache read
                self.src_lens[spec.name] = (spec.serve.max_src_len
                                            or spec.serve.max_len)
            self.engines[spec.name] = ReplicaGroup(
                wclass, model, params, spec.serve,
                sub=self.subs[spec.name], rules=self.rules,
                exec_cache=self.exec_cache, cu_axis=cu_axis,
                obs=self.obs.scoped(tenant=spec.name, wclass=wclass))
        # design-key memo for the prediction ledger's measured side (the
        # per-step path must not rebuild design dicts per tenant per step)
        self._design_keys: Dict[str, str] = {}
        self._refresh_design_keys()

    # ------------------------------------------------------------------
    def submit(self, tenant: str, tokens, max_new_tokens: int = 16,
               **kwargs) -> int:
        """Route one request to ``tenant``'s engine; returns its rid.
        Extra keywords pass through to the engine's submit (e.g. the
        enc-dec engine's forced-decoding ``prefix=``)."""
        return self.engines[tenant].submit(tokens, max_new_tokens, **kwargs)

    def sizes(self) -> Dict[str, int]:
        """Current composition: tenant -> CUs held (0 = parked)."""
        return {t: len(self.subs[t].cu_ids) if t in self.subs else 0
                for t in self.engines}

    def loads(self) -> Dict[str, TenantLoad]:
        """Per-tenant load signals sampled from the engines (group-merged
        across replicas).  Kept for telemetry/examples; the policy's
        ``decide`` input is :meth:`observe`."""
        return {t: TenantLoad(eng.pending_tokens(), eng.queue_depth,
                              eng.active_count, eng.arena_utilization())
                for t, eng in self.engines.items()}

    def observe(self) -> Dict[str, TenantObservation]:
        """Per-tenant :class:`TenantObservation` — the one record
        ``AnalyticalPolicy.decide`` consumes: replica-merged load signals,
        workload class, observed job lengths, enc-dec source capacity and
        the tenant's Stage-1 design space."""
        spaces = self._design_spaces() or {}
        return {t: TenantObservation(
                    pending_tokens=eng.pending_tokens(),
                    queue_depth=eng.queue_depth,
                    active=eng.active_count,
                    arena_utilization=eng.arena_utilization(),
                    wclass=self.classes[t],
                    recent_lengths=eng.recent_lengths(),
                    src_len=self.src_lens.get(t, 0),
                    space=spaces.get(t))
                for t, eng in self.engines.items()}

    # ------------------------------------------------------------------
    def step(self) -> Dict[str, List[Tuple[int, int]]]:
        """One fabric iteration: SLO admission check, then step every
        composed (non-parked) tenant, then maybe recompose.  Returns
        per-tenant emitted (rid, token)."""
        if (self.decide_every > 0
                and self._step_no % self.decide_every == 0):
            self._refresh_slo_observed()
        self._slo_schedule()
        emitted = {}
        for t, eng in self.engines.items():
            if t not in self.subs:
                continue                      # parked: no CUs this interval
            probe = self._stall_probe.pop(t, None)
            busy = eng.has_work
            q0 = eng.queue_depth
            t0 = time.monotonic()
            out = eng.step()
            if probe is not None:
                # pipelined dispatch returns before the step executes; the
                # probed post-move step must cover the whole step (compile
                # when cold + execution), not just the async dispatch
                eng.sync()
            dt = time.monotonic() - t0
            if probe is not None:
                probe.post_step_seconds[t] = dt
            elif busy and eng.queue_depth == q0 and self.obs.enabled:
                # decode percentiles only: idle no-op steps would deflate
                # them; admission steps (blocking prefill) and probed
                # full-sync steps would inflate them.  The timing rides the
                # engines' existing pipelined-dispatch sync point — the
                # registry/ledger writes below are host-side only.
                reg = self.obs.registry
                reg.histogram("decode_step_s", tenant=t).observe(dt)
                if out:
                    unit = dt / len(out)
                    reg.histogram("per_token_s", tenant=t).observe(unit)
                    self.ledger.observe(t, self._design_keys[t], unit,
                                        wclass=self.classes[t])
            if self.obs.enabled:
                self.obs.registry.gauge("queue_depth", tenant=t).value = \
                    eng.queue_depth
            self._tokens_emitted[t] += len(out)
            if out:
                emitted[t] = out
        self._step_no += 1
        if (self.policy is not None and self.decide_every > 0
                and self._step_no % self.decide_every == 0):
            self.autoscale()
        return emitted

    # ------------------------------------------------------------------
    # serving-side DSE plumbing (Stage-1 inputs, applied design points)
    # ------------------------------------------------------------------
    def _design_spaces(self) -> Optional[Dict[str, TenantDesignSpace]]:
        """Per-tenant Stage-1 search bounds, snapshotted from the engines
        each decide tick (None when the policy is split-only)."""
        if self.policy is None or self.policy.stage1 is None:
            return None
        out = {}
        for t, eng in self.engines.items():
            d = eng.design()
            arena = getattr(eng, "arena", None)
            per_slot = (arena.capacity // max(d["slots"], 1)
                        if arena is not None else 0)
            paged = isinstance(arena, PagedArena)
            out[t] = TenantDesignSpace(
                wclass=self.classes[t],
                max_len=eng.cfg.max_len,
                max_src=getattr(eng, "_max_src", 0),
                base_slots=d["slots"],
                base_buckets=tuple(d["buckets"] or ()),
                base_tp=d["tp"],
                base_dp=d.get("dp", 1),
                per_slot_elems=per_slot,
                tp_allowed=self.rules is not None,
                slot_cap=max(eng.cfg.slot_cap, 1),
                dp_cap=max(self.specs[t].dp_cap, 1),
                # SSM/hybrid archs prefill at exact lengths — no padding
                # for Stage 1 to price on their admission path
                prefill_bucket=(eng.cfg.prefill_bucket
                                if getattr(self.cfgs[t], "ssm", None) is None
                                else 0),
                use_kernels=getattr(eng.cfg, "use_kernels", True),
                # paged KV arenas admit by expected page footprint, not the
                # worst-case slot reservation — Stage 1 prices accordingly
                paged=paged,
                page_rows=arena.page_rows if paged else 0,
                page_elems=arena.page_elems if paged else 0)
        return out

    def _applied_points(self) -> Dict[str, DesignPoint]:
        """The live composition as applied design points (the policy's
        hysteresis baseline; parked tenants carry cus 0)."""
        out = {}
        for t, eng in self.engines.items():
            c = len(self.subs[t].cu_ids) if t in self.subs else 0
            d = eng.design()
            out[t] = DesignPoint(
                cus=c, tp=d["tp"], slots=d["slots"],
                buckets=tuple(d["buckets"]) if d["buckets"] else None,
                dp=d.get("dp", 1))
        return out

    def _refresh_design_keys(self) -> None:
        """Re-memoize each tenant's compact design key (``serve.dse
        .design_key``) for the prediction ledger's per-step measured side.
        Called at construction and after every recomposition — the hot
        step path must not rebuild design dicts per tenant per step."""
        for t, eng in self.engines.items():
            cus = len(self.subs[t].cu_ids) if t in self.subs else 0
            self._design_keys[t] = design_key(cus, eng.design())

    def _knob_delta(self, t: str, p: DesignPoint) -> Dict[str, object]:
        """Engine-knob overrides that actually change tenant ``t``'s
        configuration when design point ``p`` commits (None knobs keep; a
        slot shrink clamps at the per-replica live occupancy — streams are
        migrated, never evicted).  TP degree and slots compare at the
        point's replica-tile width: a group at dp computes on
        ``cus // dp``-wide tiles, not the whole grant."""
        eng = self.engines[t]
        d = eng.design()
        out: Dict[str, object] = {}
        dp_now = d.get("dp", 1) or 1
        dp_want = dp_now
        if p.dp is not None:
            dp_want = max(1, min(p.dp, max(p.cus, 1)))
            if dp_want != dp_now:
                out["dp"] = dp_want
        width = max(p.cus // max(dp_want, 1), 1)
        if p.tp is not None:
            want = min(p.tp, width)
            would = min(d["tp"], width) if d["tp"] else width
            if want != would:
                out["tp"] = p.tp
        if p.slots is not None:
            want_s = max(p.slots, -(-eng.active_count // max(dp_want, 1)))
            if want_s != d["slots"]:
                out["slots"] = want_s
        if p.buckets is not None and d["buckets"] is not None \
                and tuple(p.buckets) != tuple(d["buckets"]):
            out["buckets"] = tuple(p.buckets)
        return out

    @staticmethod
    def _delta_point(p: DesignPoint,
                     knobs: Optional[Dict[str, object]]) -> DesignPoint:
        """A knob delta as the DesignPoint handed to ``Engine.apply`` /
        ``warm_compile`` (absent knobs become None = keep)."""
        kn = knobs or {}
        return DesignPoint(cus=p.cus, tp=kn.get("tp"),
                           slots=kn.get("slots"),
                           buckets=kn.get("buckets"), dp=kn.get("dp"))

    def _no_change(self, points: Mapping[str, DesignPoint]) -> bool:
        """True when applying ``points`` would change nothing: same CU
        split AND no engine-knob delta on any composed tenant."""
        sizes = {t: p.cus for t, p in points.items() if p.cus > 0}
        if sizes != self._normalized(self.sizes()):
            return False
        return all(not self._knob_delta(t, p) for t, p in points.items()
                   if p.cus > 0)

    def autoscale(self) -> Optional[RecompositionEvent]:
        """Consult the policy; apply the recomposition it asks for.

        With ``prewarm_async`` the switch is two-phase: kick background
        compiles for the chosen composition (at its target design points),
        keep serving on the current one, and commit on a later tick once
        every executable is warm."""
        if self._pending_prewarm is not None:
            target, reason, futures = self._pending_prewarm
            if not all(f.done() for f in futures):
                return None               # still compiling in the background
            self._pending_prewarm = None
            for f in futures:
                f.result()                # surface background build errors
            if self._no_change(target):
                return None
            return self.recompose(target, reason=reason, overlapped=True)

        with self.obs.span("decide", step=self._step_no):
            target, reason = self.policy.decide(
                self.observe(), self.cfgs, self._applied_points(),
                self.composer.num_cus)
        target = {t: p for t, p in target.items() if p.cus > 0}
        if self._no_change(target):
            # idle decide interval: nothing committed — speculatively warm
            # the policy's runner-up design so the *next* plausible switch
            # is already compiled when its gain clears hysteresis
            self._speculative_prewarm()
            return None
        if self.warm and self.prewarm_async:
            futures = self._warm_design(target)
            self._pending_prewarm = (target, reason, futures)
            return None
        return self.recompose(target, reason=reason)

    def _warm_design(self, points: Mapping[str, DesignPoint]) -> list:
        """Submit background warm compiles for a candidate design — every
        tenant a CU move or a knob delta would touch, each warmed at its
        target design point's overrides.  Returns the futures."""
        new_subs, delta = self.composer.recompose(
            self.subs, {t: p.cus for t, p in points.items()})
        touched = set(delta.moved + delta.admitted)
        touched |= {t for t, p in points.items() if self._knob_delta(t, p)}
        return [self._pool().submit(
            lambda t=t, pt=self._delta_point(
                points[t], self._knob_delta(t, points[t])):
            self.engines[t].warm_compile(new_subs[t], pt))
            for t in sorted(touched)]

    def _speculative_prewarm(self) -> None:
        """Warm the runner-up candidate design in the background.

        Reuses the ``prewarm_async`` machinery (same single-worker pool, so
        speculative compiles never contend with a committed prewarm) and is
        gated on it: synchronous fabrics shouldn't burn serving time on
        compositions that may never commit.  Each distinct runner-up —
        keyed on the FULL design point (composition + per-tenant config) —
        is warmed once; ``warm_compile`` itself is idempotent on the shared
        executable cache."""
        # surface errors from (and drop) finished speculative compiles
        pending = []
        for f in self._spec_futures:
            if f.done():
                f.result()
            else:
                pending.append(f)
        self._spec_futures = pending
        ru = self.policy.runner_up if self.policy is not None else None
        if not (self.warm and self.prewarm_async and ru):
            return
        ru = {t: p for t, p in ru.items() if p.cus > 0}
        if not ru or self._no_change(ru):
            return
        key = tuple(sorted((t, p.cus, p.tp, p.slots, p.dp,
                            tuple(p.buckets or ())) for t, p in ru.items()))
        if key in self._spec_warmed:
            return
        if len(self._spec_warmed) > 64:      # long-lived fabric: re-warm ok
            self._spec_warmed.clear()
        futures = self._warm_design(ru)
        if not futures:
            return
        self._spec_warmed.add(key)
        self.speculative_prewarms += 1
        self._spec_futures.extend(futures)

    @staticmethod
    def _normalized(sizes: Mapping[str, int]) -> Dict[str, int]:
        return {t: s for t, s in sizes.items() if s > 0}

    def _pool(self) -> concurrent.futures.ThreadPoolExecutor:
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="prewarm")
        return self._executor

    def recompose(self, target_sizes: Mapping[str, object], *,
                  reason: str = "manual",
                  overlapped: bool = False) -> RecompositionEvent:
        """Live recomposition: grow/shrink/admit/park tenants AND apply
        per-tenant design-point deltas (DSE Stage-1 knobs).

        ``target_sizes`` maps tenant -> CU count (int, the pre-DSE contract)
        or DesignPoint (CUs + TP degree + replica count + slots + bucket
        ladder).  Only moved tenants pay a state migration; unchanged ones
        keep their devices — but a tenant whose knobs changed with its CU
        set intact is *retuned* in place (``Engine.apply``, draining
        nothing: live slots migrate inside the resize, and a dp retune
        rebalances them across the new replica set).  With warming on, the
        target composition's executables are compiled at the target design
        points before any state moves, so the post-move step is
        stall-free."""
        rc_t0 = time.perf_counter()
        before = self.sizes()
        points = {t: (v if isinstance(v, DesignPoint)
                      else DesignPoint(cus=int(v)))
                  for t, v in target_sizes.items()}
        sizes = {t: p.cus for t, p in points.items()}
        new_subs, delta = self.composer.recompose(self.subs, sizes)
        knobs = {t: self._knob_delta(t, p) for t, p in points.items()
                 if p.cus > 0}
        moved = delta.moved + delta.admitted
        retuned = tuple(t for t in knobs
                        if knobs[t] and t not in moved)
        touched = moved + retuned
        warm_s, warm_builds = 0.0, 0
        if self.warm:
            w0 = time.monotonic()
            for t in touched:
                warm_builds += self.engines[t].warm_compile(
                    new_subs[t],
                    self._delta_point(points[t], knobs.get(t)))
            warm_s = time.monotonic() - w0
        t0 = time.monotonic()
        applied: Dict[str, Dict] = {}
        for t in touched:
            eng = self.engines[t]
            with self.obs.span("migrate", tenant=t,
                               kind="move" if t in moved else "retune"):
                out = eng.apply(new_subs[t] if t in moved else None,
                                self._delta_point(points[t], knobs.get(t)))
                if out:
                    applied[t] = out
                eng.sync()
        self.subs = new_subs
        # the committed move changes device assignments, so a previously
        # prewarmed runner-up design now maps to different sub-meshes
        # (different mesh fingerprints): let it be warmed again
        self._spec_warmed.clear()
        seconds = time.monotonic() - t0
        event = RecompositionEvent(
            step=self._step_no, sizes_before=before, sizes_after=self.sizes(),
            moved=moved, unchanged=delta.unchanged,
            parked=delta.evicted, seconds=seconds, reason=reason,
            retuned=retuned, design=applied,
            warm_compile_seconds=warm_s, warm_builds=warm_builds,
            overlapped=overlapped)
        for t in touched:
            self._stall_probe[t] = event
        self.events.append(event)
        # fold-before-evict totals: the deque above is bounded, so stats()
        # aggregates accumulate here instead of re-scanning the history
        self._recompositions += 1
        self._retunes += len(retuned)
        self._recompose_seconds_total += seconds
        self._warm_compile_seconds_total += warm_s
        # predicted-vs-measured accounting: refresh the per-tenant design
        # keys for the committed composition, then record each touched
        # tenant's Stage-1 predicted per-unit cost next to the measured
        # per-step histogram that accumulates under the same key
        self._refresh_design_keys()
        for t in touched:
            p = points.get(t)
            if p is not None:
                self.ledger.commit(t, self.classes[t],
                                   self._design_keys[t], p.cost)
        if self.obs.enabled:
            self.obs.tracer.record(
                "recompose", rc_t0, time.perf_counter(),
                {"reason": reason, "moved": list(moved),
                 "retuned": list(retuned), "parked": list(delta.evicted),
                 "warm_builds": warm_builds},
                cat="recompose")
            self.obs.inc("recompositions")
        return event

    def unify(self, tenant: str, *, reason: str = "unify"
              ) -> RecompositionEvent:
        """The monolithic composition: the whole fabric for one tenant."""
        return self.recompose({tenant: self.composer.num_cus}, reason=reason)

    # ------------------------------------------------------------------
    def pending(self) -> int:
        """Total owed work units across tenants (decode steps / prompt
        tokens by class)."""
        return sum(ld.pending_tokens for ld in self.loads().values())

    def drain(self, max_steps: int = 10_000) -> Dict[str, Dict[int, List[int]]]:
        """Step until every tenant's queue, slots and in-flight dispatches
        are empty; returns per-tenant {rid: tokens} for all requests seen."""
        for _ in range(max_steps):
            busy = [t for t, eng in self.engines.items() if eng.has_work]
            if not busy:
                break
            if any(t not in self.subs for t in busy) and self.policy is None:
                # no policy to re-admit a parked tenant: give it CUs back
                self.recompose({t: 0 for t in self.engines} |
                               {t: self.composer.num_cus // max(len(busy), 1)
                                for t in busy}, reason="drain")
            self.step()
        return self.results()

    def results(self) -> Dict[str, Dict[int, List[int]]]:
        """Per-tenant ``snapshot()``: every request seen -> emitted units
        (tokens, or embedding components for encoder tenants)."""
        return {t: eng.snapshot() for t, eng in self.engines.items()}

    def decode_step_ms(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant decode step latency percentiles (milliseconds), read
        from the fabric registry's ``decode_step_s{tenant}`` histograms
        (empty with telemetry off — latency accounting is the registry's)."""
        out = {}
        for t in self.engines:
            h = self.obs.registry.merged_histogram("decode_step_s", tenant=t)
            if h.count == 0:
                continue
            out[t] = {"p50": round(h.quantile(0.5) * 1e3, 3),
                      "p95": round(h.quantile(0.95) * 1e3, 3),
                      "n": h.count}
        return out

    # ------------------------------------------------------------------
    # telemetry export surface (repro.obs)
    # ------------------------------------------------------------------
    def metrics(self) -> MetricsRegistry:
        """One merged registry across the whole stack: the fabric's own
        step/SLO histograms plus every tenant engine's per-replica
        registries (retired dp replicas included), with the shared
        executable cache folded in as gauges."""
        merged = MetricsRegistry()
        merged.merge(self.obs.registry)
        for eng in self.engines.values():
            merged.merge(eng.metrics())
        snap = self.exec_cache.snapshot()
        for k, v in snap.items():
            merged.gauge(f"exec_cache_{k}").set(float(v))
        merged.counter("recompositions_total").inc(self._recompositions)
        merged.counter("retunes_total").inc(self._retunes)
        return merged

    def metrics_snapshot(self) -> Dict[str, object]:
        """JSON-friendly dump of :meth:`metrics` (the ``--metrics-json``
        payload)."""
        return self.metrics().snapshot()

    def dump_trace(self, path: str) -> str:
        """Write the span ring buffer as Chrome/Perfetto trace-event JSON
        (load in ``chrome://tracing`` or https://ui.perfetto.dev); returns
        the path written."""
        return self.obs.tracer.dump(path)

    # ------------------------------------------------------------------
    # SLO-aware scheduling (docs/scheduling.md)
    # ------------------------------------------------------------------
    def _refresh_slo_observed(self) -> None:
        """Re-sample each SLO-tracked tenant's observed p99s (ms) from the
        obs histograms.  Decide-cadence only: merging replica registries
        per step would tax the hot path, and the observed quantiles move
        slowly anyway."""
        for t, eng in self.engines.items():
            slo = self.specs[t].slo
            if slo is None or not slo.tracked():
                continue
            if slo.ttft_p99_ms > 0:
                h = eng.metrics().merged_histogram("ttft_s")
                if h.count:
                    self._slo_obs[(t, "ttft_p99_ms")] = \
                        h.quantile(0.99) * 1e3
            if slo.per_token_p99_ms > 0:
                h = self.obs.registry.merged_histogram("per_token_s",
                                                       tenant=t)
                if h.count:
                    self._slo_obs[(t, "per_token_p99_ms")] = \
                        h.quantile(0.99) * 1e3

    def _slo_preempt(self, t: str, why: str) -> bool:
        rid = self.engines[t].preempt_one()
        if rid is None:
            return False
        self._slo_preemptions += 1
        if self.obs.enabled:
            self.obs.inc("slo_preemptions")
            self.obs.inc(f"slo_preemptions_{why}")
        return True

    def _slo_schedule(self) -> None:
        """The SLO-aware admission/preemption pass, run before each fabric
        step.

        TTFT protection: a tenant whose head-of-line queue wait has burned
        half its p99 TTFT budget (a quarter once its *observed* TTFT p99
        is already over target) gets its slackest live stream preempted,
        so the freed slot/pages admit the waiting request in this very
        step's ``_admit``.  Per-token protection: a tenant whose observed
        per-token p99 breached target sheds one stream (smaller batch =>
        faster steps), at most one parked at a time so shedding never
        cascades.  Preemption saves exact device state; the victim
        re-admits later and continues bit-identically (greedy decode rows
        are batch-independent, pinned by tests/test_preempt_chaos.py)."""
        if not self.slo_preempt:
            return
        for t, eng in self.engines.items():
            if t not in self.subs:
                continue                     # parked tenant: no CUs at all
            slo = self.specs[t].slo
            if slo is None or not slo.tracked():
                continue
            if slo.ttft_p99_ms > 0 and eng.queue_depth > 0:
                breached = (self._slo_obs.get((t, "ttft_p99_ms"), 0.0)
                            > slo.ttft_p99_ms)
                frac = 0.25 if breached else 0.5
                if (eng.queue_head_wait_s() * 1e3
                        >= frac * slo.ttft_p99_ms):
                    if self._slo_preempt(t, "ttft"):
                        continue
            if (slo.per_token_p99_ms > 0 and eng.active_count > 1
                    and eng.preempted_depth == 0
                    and self._slo_obs.get((t, "per_token_p99_ms"), 0.0)
                    > slo.per_token_p99_ms):
                self._slo_preempt(t, "per_token")

    def slo_attainment(self) -> Dict[str, object]:
        """Per-tenant SLO attainment: for every declared target, the
        fraction of observed TTFTs / per-token latencies at or under it
        (``Histogram.fraction_below``) and whether that fraction meets the
        target's own percentile, plus the preemption counters the
        scheduler spent getting there.  TTFT histograms come from the
        engines' merged registries; per-token from the fabric's filtered
        steady-state histograms (same sources as :meth:`slo_summary`)."""
        merged = self.metrics()
        tenants: Dict[str, Dict[str, object]] = {}
        for t, eng in self.engines.items():
            slo = self.specs[t].slo
            if slo is None or not slo.tracked():
                continue
            row: Dict[str, object] = {
                "class": self.classes[t],
                "preemptions": int(getattr(eng, "preempt_count", 0)),
                "parked": int(getattr(eng, "preempted_depth", 0)),
            }
            for metric, name, src, targets in (
                    ("ttft", "ttft_s", merged,
                     ((0.50, slo.ttft_p50_ms), (0.99, slo.ttft_p99_ms))),
                    ("per_token", "per_token_s", self.obs.registry,
                     ((0.50, slo.per_token_p50_ms),
                      (0.99, slo.per_token_p99_ms)))):
                if not any(tgt > 0 for _, tgt in targets):
                    continue
                h = src.merged_histogram(name, tenant=t)
                ent: Dict[str, object] = {"n": h.count}
                for q, tgt in targets:
                    if tgt <= 0:
                        continue
                    att = (h.fraction_below(tgt * 1e-3)
                           if h.count else 0.0)
                    ent[f"p{int(q * 100)}"] = {
                        "target_ms": tgt,
                        "observed_ms": (round(h.quantile(q) * 1e3, 3)
                                        if h.count else None),
                        "attainment": round(att, 4),
                        "met": bool(h.count) and att + 1e-12 >= q,
                    }
                row[metric] = ent
            tenants[t] = row
        return {"tenants": tenants,
                "slo_preemptions": self._slo_preemptions}

    def slo_summary(self) -> Dict[str, object]:
        """Per-tenant serving SLO percentiles (milliseconds): TTFT,
        per-token latency, decode-step latency and queue wait, plus the
        predicted-vs-measured aggregate.  TTFT/queue-wait come from the
        engines' merged registries; per-token and step latency from the
        fabric-level filtered histograms."""
        merged = self.metrics()
        per_tenant: Dict[str, Dict[str, object]] = {}
        for t in self.engines:
            row: Dict[str, object] = {"class": self.classes[t]}
            for name, label in (("ttft_s", "ttft_ms"),
                                ("queue_wait_s", "queue_wait_ms"),
                                ("per_token_s", "per_token_ms"),
                                ("decode_step_s", "decode_step_ms")):
                # step latency comes from the fabric-level filtered
                # histogram (steady-state decode only); the merged view
                # would fold in the engines' unfiltered step timer, which
                # includes cold-compile and admission-adjacent steps
                src = (self.obs.registry if name in
                       ("decode_step_s", "per_token_s") else merged)
                h = src.merged_histogram(name, tenant=t)
                if h.count == 0:
                    continue
                row[label] = {"p50": round(h.quantile(0.5) * 1e3, 4),
                              "p99": round(h.quantile(0.99) * 1e3, 4),
                              "n": h.count}
            per_tenant[t] = row
        return {"tenants": per_tenant,
                "predicted_vs_measured":
                    self.ledger.summary()["aggregate"]}

    def stats(self) -> Dict[str, object]:
        """Fabric-wide telemetry: per-tenant emitted units and classes,
        recomposition timings (seconds), per-tenant migrations and cold
        builds, shared-cache hit counts, speculative prewarms, decode step
        latency percentiles (ms), predicted-vs-measured accounting and the
        current device composition.  Counts and totals come from fold
        counters, not the bounded ``events`` deque — they stay correct
        after old events are evicted."""
        return {
            "steps": self._step_no,
            "workload_classes": dict(self.classes),
            # per-tenant emitted units: tokens for decode/ssm tenants,
            # completed sequences (embeddings) for encoder tenants
            "tokens_emitted": dict(self._tokens_emitted),
            # applied design points (the serving DSE's Stage-1 knobs)
            "design_points": {
                t: {"cus": len(self.subs[t].cu_ids) if t in self.subs else 0,
                    "tp": d["tp"], "slots": d["slots"],
                    "buckets": list(d["buckets"]) if d["buckets"] else None,
                    "dp": d.get("dp", 1)}
                for t, d in ((t, eng.design())
                             for t, eng in self.engines.items())},
            "retunes": self._retunes,
            "recompositions": self._recompositions,
            "recompose_seconds": round(self._recompose_seconds_total, 4),
            "warm_compile_seconds": round(self._warm_compile_seconds_total,
                                          4),
            "recompose_seconds_recent": [round(e.seconds, 4)
                                         for e in self.events],
            "preemptions": {t: int(getattr(eng, "preempt_count", 0))
                            for t, eng in self.engines.items()},
            "slo_preemptions": self._slo_preemptions,
            "reshards_per_tenant": {t: eng.reshard_count
                                    for t, eng in self.engines.items()},
            "compile_builds": {t: eng.compile_builds
                               for t, eng in self.engines.items()},
            "shared_exec_cache": {"builds": self.exec_cache.builds,
                                  "hits": self.exec_cache.hits},
            "speculative_prewarms": self.speculative_prewarms,
            "decode_step_ms": self.decode_step_ms(),
            "predicted_vs_measured": self.ledger.summary(),
            "composition": {t: list(self.subs[t].cu_ids)
                            for t in self.subs},
        }
