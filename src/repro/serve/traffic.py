"""Open-loop traffic generation for the serving fabric.

A schedule is a *pure* function of ``(profile, tenants, requests_per_tenant,
seed)``: every arrival step, prompt length and generation budget comes from
one ``numpy`` generator seeded once, so the same seed replays the identical
arrival process (pinned by tests/test_traffic.py) and a benchmark's paired
arms (paged vs slot-granular, preemptive vs not) see the same offered load.

Profiles (``--scenario`` in ``repro.launch.serve``):

* ``bursty`` — each tenant's requests land i.i.d. uniform over the horizon:
  overlapping per-tenant bursts, the PR-5 recomposition driver.
* ``diurnal`` — arrival intensity follows one raised-cosine "day" over the
  horizon (quiet at the edges, peak mid-run), sampled by inverse CDF; load
  swells and ebbs smoothly under the policy's feet.
* ``flash-crowd`` — every tenant trickles uniformly, then the *first*
  tenant's whole request budget lands inside a narrow window a third of the
  way in: queue depth spikes far past the slot pool, the regime the
  SLO-aware scheduler's preemption exists for.
* ``heavy-tail`` — uniform arrivals, but generation budgets draw from a
  Pareto tail (a few requests run many times longer than the median): the
  long-running streams accumulate pages and become the natural preemption
  victims.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

__all__ = ["Arrival", "PROFILES", "arrival_schedule"]

PROFILES = ("bursty", "diurnal", "flash-crowd", "heavy-tail")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request arrival."""

    step: int                        # fabric step the request arrives at
    tenant: str
    prompt_len: int
    max_new: int


def _horizon(requests_per_tenant: int) -> int:
    return max(4 * requests_per_tenant, 8)


def arrival_schedule(profile: str, tenants: Sequence[str],
                     requests_per_tenant: int, seed: int, *,
                     max_new: int = 16) -> List[Arrival]:
    """The deterministic arrival schedule: ``requests_per_tenant`` arrivals
    per tenant, sorted by (step, submission order).  ``max_new`` is the
    per-request generation budget (the ``heavy-tail`` profile draws its own
    tail around it)."""
    if profile not in PROFILES:
        raise ValueError(f"unknown traffic profile {profile!r}; "
                         f"choose from {PROFILES}")
    names = list(tenants)
    R = int(requests_per_tenant)
    H = _horizon(R)
    rng = np.random.default_rng(seed)
    out: List[Arrival] = []

    def plen() -> int:
        return int(rng.integers(4, 24))

    if profile == "bursty":
        for t in names:
            for _ in range(R):
                out.append(Arrival(int(rng.integers(0, H)), t, plen(),
                                   max_new))
    elif profile == "diurnal":
        # raised-cosine intensity 1 - cos(2*pi*x) over x in [0, 1): the
        # inverse-CDF lookup turns uniform draws into one smooth "day"
        grid = np.linspace(0.0, 1.0, 513)
        cdf = grid - np.sin(2.0 * np.pi * grid) / (2.0 * np.pi)
        for t in names:
            steps = np.interp(rng.random(R), cdf, grid) * H
            for s in steps:
                out.append(Arrival(min(int(s), H - 1), t, plen(), max_new))
    elif profile == "flash-crowd":
        flash_at = H // 3
        window = max(R // 8, 1)
        for i, t in enumerate(names):
            for _ in range(R):
                step = (int(flash_at + rng.integers(0, window)) if i == 0
                        else int(rng.integers(0, H)))
                out.append(Arrival(step, t, plen(), max_new))
    else:                            # heavy-tail
        cap = 8 * max_new
        for t in names:
            for _ in range(R):
                tail = int(max_new * (1.0 + rng.pareto(1.5)))
                out.append(Arrival(int(rng.integers(0, H)), t, plen(),
                                   min(tail, cap)))
    return sorted(out, key=lambda a: a.step)
