"""Tests for the §Perf optimization variants: they must compute the SAME
function as the baselines (gradients included), plus the TPU-profile DSE
bridge over the assigned architectures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CELLS_BY_NAME, get_config, get_reduced
from repro.core.schedule import validate
from repro.core.tpu_modes import arch_workload, dse_for_arch
from repro.distribution import strip
from repro.models import build_model
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# fused / fused_serial selective scan == chunked baseline (values + grads)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("impl", ["fused", "fused_serial"])
@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_fused_ssm_matches_baseline(impl, chunk):
    cfg = get_reduced("falcon-mamba-7b")
    p = strip(S.mamba_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)

    def f(impl_):
        return lambda p_, x_: jnp.sum(
            jnp.sin(S.mamba_fwd(p_, cfg, x_, chunk=chunk, impl=impl_)))

    np.testing.assert_allclose(f("chunked")(p, x), f(impl)(p, x),
                               rtol=1e-5, atol=1e-5)
    g_base = jax.grad(f("chunked"))(p, x)
    g_new = jax.grad(f(impl))(p, x)
    for k in g_base:
        a = np.asarray(g_base[k], np.float32)
        b = np.asarray(g_new[k], np.float32)
        denom = np.abs(a).max() + 1e-9
        assert np.abs(a - b).max() / denom < 1e-3, (impl, k)
    gx_base = jax.grad(f("chunked"), argnums=1)(p, x)
    gx_new = jax.grad(f(impl), argnums=1)(p, x)
    np.testing.assert_allclose(gx_base, gx_new, rtol=1e-3, atol=1e-5)


def test_fused_ssm_in_full_model_loss():
    """End-to-end: hymba loss identical across ssm impls."""
    cfg = get_reduced("hymba-1.5b")
    m = build_model(cfg)
    params = strip(m.init(jax.random.key(0)))
    tok = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    base, _ = m.loss(params, batch, ssm_impl="chunked")
    for impl in ("fused", "fused_serial"):
        got, _ = m.loss(params, batch, ssm_impl=impl)
        assert abs(float(base) - float(got)) < 1e-2, impl


# ---------------------------------------------------------------------------
# bf16-wire attention: bf16 inputs with f32 accumulation stay close to the
# f32 reference (the MXU-native contract)
# ---------------------------------------------------------------------------

def test_bf16_attention_accuracy():
    from repro.models import layers as L
    rng = np.random.default_rng(0)
    B, Sq, H, D = 2, 64, 4, 32
    q32 = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k32 = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    v32 = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    ref = L.blockwise_attention(q32, k32, v32, causal=True, block_size=16)
    out = L.blockwise_attention(q32.astype(jnp.bfloat16),
                                k32.astype(jnp.bfloat16),
                                v32.astype(jnp.bfloat16),
                                causal=True, block_size=16)
    err = np.abs(np.asarray(out, np.float32) - np.asarray(ref)).max()
    assert err < 5e-2, err


def test_attn_block_size_invariance():
    """Different attention block sizes compute the same function."""
    cfg = get_reduced("qwen2.5-32b")
    m = build_model(cfg)
    params = strip(m.init(jax.random.key(0)))
    tok = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    a, _ = m.loss(params, batch, attn_block=4)
    b, _ = m.loss(params, batch, attn_block=16)
    assert abs(float(a) - float(b)) < 1e-2


# ---------------------------------------------------------------------------
# TPU-profile DSE over assigned-arch layer DAGs (the paper's framework
# applied to the pod deployment)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2.5-32b", "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "arctic-480b"])
def test_arch_workload_lowering(arch):
    cfg = get_config(arch)
    wl = arch_workload(cfg, CELLS_BY_NAME["train_4k"])
    assert len(wl.layers) >= 2
    assert wl.total_flops > 0
    # DAG is acyclic and deps in range
    for i, l in enumerate(wl.layers):
        assert all(d < i for d in l.deps)


def test_dse_for_arch_produces_valid_tpu_schedule():
    cfg = get_config("qwen2.5-32b")
    res = dse_for_arch(cfg, CELLS_BY_NAME["train_4k"], seed=0)
    validate(res.problem, res.schedule)
    assert res.makespan > 0
    # diverse layer shapes should select more than one distinct mode/tile
    tiles = {pl.tile for pl in res.plan.layers}
    assert len(tiles) >= 2, "DSE collapsed to a single tile for diverse MMs"
