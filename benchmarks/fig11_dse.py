"""Fig. 11 reproduction: DSE search efficiency — exact (MILP-equivalent
branch-and-bound) vs GA on the paper's two synthetic task sets.

  Config-1: 50 layers x 50 candidate modes each
  Config-2: 50 layers x 5000 candidate modes each

Paper findings reproduced: on Config-1 the GA converges to a near-optimal
point (~3% gap) much faster than the exact solver; on Config-2 the exact
solver cannot finish within its budget while the GA still returns a good
point in minutes.  Budgets are scaled to this 1-core container.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.ga import GAConfig, solve_ga
from repro.core.milp import solve_exact
from repro.core.schedule import Mode, ScheduleProblem


def synth_problem(n_layers: int, n_cands: int, seed: int = 0,
                  f_max: int = 16, c_max: int = 8) -> ScheduleProblem:
    rng = np.random.default_rng(seed)
    deps = []
    for i in range(n_layers):
        ds = tuple(int(j) for j in range(max(0, i - 4), i)
                   if rng.random() < 0.35)
        deps.append(ds)
    modes = []
    for i in range(n_layers):
        ms = []
        base = rng.uniform(1.0, 8.0)
        for k in range(n_cands):
            cus = int(rng.integers(1, c_max + 1))
            fmus = int(rng.integers(3, f_max + 1))
            # more resources -> faster, with diminishing returns + noise
            lat = base * (1.0 + 2.0 / cus + 1.0 / fmus) * rng.uniform(0.9, 1.1)
            ms.append(Mode(fmus=fmus, cus=cus, latency=float(lat)))
        modes.append(tuple(ms))
    return ScheduleProblem(tuple(deps), tuple(modes), f_max, c_max)


def run(check: bool = True, exact_budget_s: float = 30.0,
        ga_budget_s: float = 45.0):
    results = {}
    for name, n_cands in (("Config-1", 50), ("Config-2", 5000)):
        prob = synth_problem(50, n_cands, seed=1)
        t0 = time.monotonic()
        ga = solve_ga(prob, GAConfig(population=32, generations=400,
                                     seed=0, time_limit_s=ga_budget_s,
                                     patience=60))
        ga_s = time.monotonic() - t0
        ex = solve_exact(prob, time_limit_s=exact_budget_s,
                         incumbent=ga.schedule)
        gap = (ga.makespan - ex.makespan) / ex.makespan if ex.makespan else 0.0
        results[name] = {
            "ga_time_s": ga_s, "ga_makespan": ga.makespan,
            "ga_generations": ga.generations_run,
            "exact_time_s": ex.wall_s, "exact_makespan": ex.makespan,
            "exact_finished": ex.optimal, "gap_vs_exact": gap,
            "lower_bound": prob.lower_bound(),
        }
    if check:
        # the exact solver must NOT finish Config-2-sized trees in budget
        assert not results["Config-2"]["exact_finished"]
        # GA stays close to the best exact incumbent (paper: ~3%)
        assert results["Config-1"]["gap_vs_exact"] <= 0.10
        # and is sane vs the problem lower bound
        for r in results.values():
            assert r["ga_makespan"] >= r["lower_bound"] - 1e-9
    return results


def main():
    res = run()
    for name, r in res.items():
        print(f"fig11,{name},ga={r['ga_time_s']:.1f}s,"
              f"exact={r['exact_time_s']:.1f}s"
              f"(finished={r['exact_finished']}),"
              f"gap={r['gap_vs_exact']*100:.1f}%,"
              f"lb={r['lower_bound']:.1f},ga_ms={r['ga_makespan']:.1f}")
    return res


if __name__ == "__main__":
    main()
