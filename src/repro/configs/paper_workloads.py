"""The paper's own evaluation workloads, expressed as MM-layer DAGs.

FILCO's experiments (Figs 1, 8–10) run on MLP / DeiT / PointNet / BERT-n
matrix-multiply workloads.  The DSE consumes a DAG of layers where each node
is a matmul with shape (M, K, N); these builders generate exactly those DAGs.

Batch conventions follow the paper's framing: BERT-n = BERT-base encoder with
sequence length n; MLP-L/S from [Wang et al., arXiv:1907.10701]; DeiT-B/S from
[arXiv:2012.12877]; PointNet per [arXiv:1612.00593] with its T-Net MMs (the
source of its "highest diversity").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MMLayer:
    """One matmul node: (M x K) @ (K x N), ``deps`` = indices it depends on."""

    name: str
    m: int
    k: int
    n: int
    deps: Tuple[int, ...] = ()

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n

    @property
    def bytes_io(self) -> float:  # fp32 operands + result, single pass
        return 4.0 * (self.m * self.k + self.k * self.n + self.m * self.n)


@dataclasses.dataclass(frozen=True)
class MMWorkload:
    name: str
    layers: Tuple[MMLayer, ...]

    @property
    def total_flops(self) -> float:
        return sum(l.flops for l in self.layers)

    def diversity(self) -> float:
        """Shape-diversity metric: mean pairwise log-ratio distance of
        (M,K,N) across layers (0 = all identical).  Used to place workloads
        on the Fig. 9 diversity axis."""
        import math

        dims = [(l.m, l.k, l.n) for l in self.layers]
        if len(dims) < 2:
            return 0.0
        tot, cnt = 0.0, 0
        for i in range(len(dims)):
            for j in range(i + 1, len(dims)):
                a, b = dims[i], dims[j]
                tot += sum(abs(math.log2(x / y)) for x, y in zip(a, b)) / 3.0
                cnt += 1
        return tot / cnt


def _chain(layers: Sequence[Tuple[str, int, int, int]]) -> Tuple[MMLayer, ...]:
    out: List[MMLayer] = []
    for i, (nm, m, k, n) in enumerate(layers):
        out.append(MMLayer(nm, m, k, n, deps=(i - 1,) if i else ()))
    return tuple(out)


# ---------------------------------------------------------------------------
# MLP (near-square MMs, lowest diversity).  MLP-L/S per the paper's framing of
# large vs small classifier MLPs (batch x hidden chains).
# ---------------------------------------------------------------------------

def mlp(batch: int = 1024, hidden: int = 4096, depth: int = 6, name: str = "MLP-L") -> MMWorkload:
    return MMWorkload(name, _chain([(f"fc{i}", batch, hidden, hidden) for i in range(depth)]))


MLP_L = mlp(1024, 4096, 6, "MLP-L")
MLP_M = mlp(512, 2048, 6, "MLP-M")
MLP_S = mlp(64, 512, 6, "MLP-S")


# ---------------------------------------------------------------------------
# BERT-base encoder at sequence length s: per layer
#   QKV (3x), attn scores/values (per-head, folded into two batched MMs),
#   output proj, FFN up, FFN down.  Medium diversity.
# ---------------------------------------------------------------------------

def bert(seq: int, d: int = 768, heads: int = 12, d_ff: int = 3072,
         layers: int = 12, name: str | None = None) -> MMWorkload:
    hd = d // heads
    nodes: List[MMLayer] = []
    prev = ()
    for li in range(layers):
        base = len(nodes)
        q = MMLayer(f"l{li}.q", seq, d, d, prev)
        k = MMLayer(f"l{li}.k", seq, d, d, prev)
        v = MMLayer(f"l{li}.v", seq, d, d, prev)
        nodes += [q, k, v]
        # scores: heads x (seq x hd) @ (hd x seq)  -> flattened batched MM
        s = MMLayer(f"l{li}.qk", heads * seq, hd, seq, (base, base + 1))
        nodes.append(s)
        a = MMLayer(f"l{li}.av", heads * seq, seq, hd, (base + 3, base + 2))
        nodes.append(a)
        o = MMLayer(f"l{li}.o", seq, d, d, (base + 4,))
        nodes.append(o)
        f1 = MMLayer(f"l{li}.ffn1", seq, d, d_ff, (base + 5,))
        nodes.append(f1)
        f2 = MMLayer(f"l{li}.ffn2", seq, d_ff, d, (base + 6,))
        nodes.append(f2)
        prev = (base + 7,)
    return MMWorkload(name or f"BERT-{seq}", tuple(nodes))


BERT_32 = bert(32)
BERT_64 = bert(64)
BERT_128 = bert(128)
BERT_256 = bert(256)
BERT_512 = bert(512)
BERT_SERIES = (BERT_32, BERT_64, BERT_128, BERT_256, BERT_512)


# ---------------------------------------------------------------------------
# DeiT (ViT): patches = (img/16)^2 (+1 cls).  DeiT-B: d=768, DeiT-S: d=384.
# Attention vs FFN shape mismatch = medium-high diversity.
# ---------------------------------------------------------------------------

def deit(d: int = 768, heads: int = 12, layers: int = 12, img: int = 224,
         name: str = "DeiT-B") -> MMWorkload:
    seq = (img // 16) ** 2 + 1
    return bert(seq, d=d, heads=heads, d_ff=4 * d, layers=layers, name=name)


DEIT_B = deit(768, 12, 12, 224, "DeiT-L")   # paper labels the larger DeiT "DeiT-L"
DEIT_S = deit(384, 6, 12, 224, "DeiT-S")


# ---------------------------------------------------------------------------
# PointNet: per-point shared MLPs (N points x small channels) + T-Net (3x3 and
# 64x64 transform regressors) -> extreme intra-model shape variance.
# ---------------------------------------------------------------------------

def pointnet(n_points: int = 1024, name: str = "PointNet") -> MMWorkload:
    nodes: List[MMLayer] = []

    def add(nm, m, k, n, deps=()):
        nodes.append(MMLayer(nm, m, k, n, deps))
        return len(nodes) - 1

    # input T-Net (3x3): mlp 3->64->128->1024, fc 1024->512->256->9
    i0 = add("tnet1.c1", n_points, 3, 64)
    i1 = add("tnet1.c2", n_points, 64, 128, (i0,))
    i2 = add("tnet1.c3", n_points, 128, 1024, (i1,))
    i3 = add("tnet1.f1", 1, 1024, 512, (i2,))
    i4 = add("tnet1.f2", 1, 512, 256, (i3,))
    i5 = add("tnet1.f3", 1, 256, 9, (i4,))
    t1 = add("tnet1.apply", n_points, 3, 3, (i5,))
    # mlp1 3->64->64
    m0 = add("mlp1.c1", n_points, 3, 64, (t1,))
    m1 = add("mlp1.c2", n_points, 64, 64, (m0,))
    # feature T-Net (64x64)
    f0 = add("tnet2.c1", n_points, 64, 64, (m1,))
    f1 = add("tnet2.c2", n_points, 64, 128, (f0,))
    f2 = add("tnet2.c3", n_points, 128, 1024, (f1,))
    f3 = add("tnet2.f1", 1, 1024, 512, (f2,))
    f4 = add("tnet2.f2", 1, 512, 256, (f3,))
    f5 = add("tnet2.f3", 1, 256, 64 * 64, (f4,))
    t2 = add("tnet2.apply", n_points, 64, 64, (f5, m1))
    # mlp2 64->64->128->1024
    g0 = add("mlp2.c1", n_points, 64, 64, (t2,))
    g1 = add("mlp2.c2", n_points, 64, 128, (g0,))
    g2 = add("mlp2.c3", n_points, 128, 1024, (g1,))
    # classifier head 1024->512->256->40
    h0 = add("cls.f1", 1, 1024, 512, (g2,))
    h1 = add("cls.f2", 1, 512, 256, (h0,))
    add("cls.f3", 1, 256, 40, (h1,))
    return MMWorkload(name, tuple(nodes))


POINTNET = pointnet(1024, "PointNet-L")
POINTNET_S = pointnet(256, "PointNet-S")

PAPER_WORKLOADS: Dict[str, MMWorkload] = {
    w.name: w
    for w in (MLP_L, MLP_M, MLP_S, BERT_32, BERT_64, BERT_128, BERT_256,
              BERT_512, DEIT_B, DEIT_S, POINTNET, POINTNET_S)
}
