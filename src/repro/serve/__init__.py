from repro.serve.engine import Request, ServeConfig, ServeEngine

__all__ = ["Request", "ServeConfig", "ServeEngine"]
