"""Enc-dec serving engine: full encode→decode jobs through the composed
fabric — the fourth workload class, completing FILCO's "diverse workloads on
one fabric" story (paper §1; Herald's scheduling win comes from covering
*every* class in the mix).

An enc-dec job (e.g. seamless-m4t speech-to-text) is two phases with
opposite bound resources:

* **encode** — one compute-bound bidirectional pass over the source frames
  (:meth:`Model.encode`'s encoder stack).  The engine batches the encodes of
  every request admitted in the same step and compiles the batched program
  **per source-length bucket** (``ServeConfig.len_buckets``), so short
  sources skip the padded FLOPs of the full-capacity program.  Each row's
  key padding is masked (``Model.encode(lens=...)``), so a job's encode is
  bit-identical across buckets — the ladder is pure performance tuning, and
  the serving DSE's Stage 1 can swap it live
  (``apply(point=DesignPoint(buckets=...))``) without touching numerics;
* **decode** — pooled-slot autoregressive decode on the shared
  continuous-batching substrate of :class:`DecodeEngine` (slots, pipelined
  dispatch, AOT executables, ``ShardingPlan`` TP, live ``reshard_to`` /
  ``apply``), where each step additionally reads the slot's
  **cross-attention source cache**: per-layer (max_slots, max_src_len,
  kv_heads, head_dim) K/V computed from the encoder output once at admission
  and masked per row by the slot's true source length (``cache["src_len"]``,
  an int32 vector the model side threads through
  ``init_cache``/``decode_step``).

The job contract (``submit(source, max_new_tokens, prefix=...)``):

* ``source`` is the source sequence — int token ids (embedded as stand-in
  frames, the audio frontend being a STUB) **or** precomputed frame
  embeddings as a float (S, d_model) array from a real frontend; both run
  the same bidirectional encoder and pay the same per-frame arena rows;
* ``prefix`` is an optional target-token prefix for **forced decoding**:
  the decoder prompt becomes ``[bos] + prefix`` (prefilled through the
  fused slot-prefill program at a bucketed prompt length), and the stream
  then continues greedily for ``max_new_tokens`` — without it the decoder
  starts from ``ServeConfig.bos_id`` alone.

Admission accounting covers *both* caches: a request holds
``src_len + len(decoder prompt) + max_new_tokens`` arena rows (source frames
+ BOS/prefix + decode budget — cross K/V and decoder KV have the same
per-row footprint of ``2·kv_heads·head_dim`` elements per layer), so the
FlexArena fit check backpressures on source-cache pressure exactly like it
does on KV pressure.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.composer import mesh_fingerprint
from repro.core.dse import DesignPoint
from repro.distribution import partitioning as part
from repro.models.model import Model
from repro.workloads.base import length_buckets, pick_bucket
from repro.workloads.compile_cache import ExecutableCache
from repro.workloads.decode import (DecodeEngine, Request, ServeConfig,
                                    _mesh_of, _round_block, _write_slot)

# source kinds a request's batched encode groups by: token ids embedded as
# stand-in frames (frontend STUB) vs precomputed frame embeddings
TOKENS, FRAMES = "tokens", "frames"


class EncDecEngine(DecodeEngine):
    """Full encode→decode serving on enc-dec archs (the ``encdec`` workload
    class): batched bucketed source encode at admission (token or
    precomputed-frame sources), per-slot cross-attention source cache,
    forced decoding from target prefixes, inherited pooled-slot decode (see
    the module docstring; the Engine-protocol contract is
    docs/workloads.md)."""

    workload_class = "encdec"

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 mesh=None, rules: Optional[part.ShardingRules] = None,
                 exec_cache: Optional[ExecutableCache] = None,
                 obs=None):
        mc = model.cfg
        if not (mc.is_encdec and mc.cross_attention):
            raise ValueError(
                f"EncDecEngine serves encoder-decoder archs with "
                f"cross-attention; {mc.name!r} is family={mc.family!r} "
                "(use DecodeEngine/SSMEngine for decoder-only archs, or "
                "EncoderEngine for embedding-only traffic)")
        # source-cache capacity and encode-program buckets must exist before
        # super().__init__ builds the pooled/single caches through the
        # _init_cache_ann hook (and the config key through _config_key)
        self._max_src = cfg.max_src_len or cfg.max_len
        self._src_buckets = length_buckets(cfg.len_buckets, self._max_src)
        self._bucket_hits: Dict[int, int] = {b: 0 for b in self._src_buckets}
        # decoder-prompt program lengths seen (1 = the BOS-only default;
        # forced decoding adds bucketed prefix lengths) and source kinds
        # seen — both bound what warm_compile builds per candidate
        self._dec_lens = {1}
        self._src_kinds = {TOKENS}
        super().__init__(model, params, cfg, mesh=mesh, rules=rules,
                         exec_cache=exec_cache, obs=obs)
        # the token-bucketed prefill programs of the base engine never
        # dispatch (enc-dec prefills through the fused slot-prefill
        # program), so warm_compile must not burn time building them
        self._prefill_lens = set()

    # ------------------------------------------------------------------
    # cache shapes / admission accounting (hooks from DecodeEngine)
    # ------------------------------------------------------------------
    def _config_key(self, slots: int, buckets=None) -> Tuple:
        """The serve dims that shape enc-dec programs extend the shared-cache
        config fingerprint: two tenants differing only in source capacity or
        bucket ladder must not share compiled executables.  ``buckets``
        prices a prospective ladder (warm_compile on a candidate design
        point)."""
        ladder = (length_buckets(buckets, self._max_src)
                  if buckets is not None else self._src_buckets)
        return super()._config_key(slots) + (self._max_src, ladder)

    def _init_cache_ann(self, batch: int):
        """Decoder KV pool plus per-slot cross-attention source cache
        (per-layer (batch, max_src, kv_heads, head_dim) K/V and the (batch,)
        int32 ``src_len`` mask bounds)."""
        return self.model.init_cache(batch, self.cfg.max_len,
                                     src_len=self._max_src)

    def _arena_capacity(self) -> int:
        """Arena elements mirroring the device pools: per slot, ``max_len``
        decoder-KV rows plus ``max_src`` source-cache rows (cross K/V and
        decoder KV share the 2·kv_heads·head_dim per-layer row footprint)."""
        return (self.cfg.max_slots * (self.cfg.max_len + self._max_src)
                * self._per_token_elems)

    def _dec_prompt(self, req: Request) -> np.ndarray:
        """The decoder prompt: BOS plus the forced-decoding prefix."""
        bos = np.asarray([self.cfg.bos_id], np.int32)
        if req.prefix is None or len(req.prefix) == 0:
            return bos
        return np.concatenate([bos, np.asarray(req.prefix, np.int32)])

    def _slot_rows(self, req: Request) -> int:
        """Arena rows a job occupies: its source frames (cross-cache side)
        plus the decoder prompt (BOS + forced prefix) + generation budget
        (decoder-KV side)."""
        return (len(req.tokens) + len(self._dec_prompt(req))
                + req.max_new_tokens)

    def _row_cap(self) -> int:
        # per-slot device rows mirror both pools: decoder KV + cross cache
        return self.cfg.max_len + self._max_src

    def _live_rows(self, req: Request) -> int:
        """Paged coverage for the next dispatch: the full source cache rows
        (written at admission by the batched encode, never grows) plus the
        live decoder-KV occupancy + the row the dispatch writes."""
        return min(len(req.tokens) + self._dec_len(req) + 1, self._row_cap())

    def _oversized(self, req: Request) -> bool:
        """Hard reject: source longer than the cross cache, or a decoder
        prompt (BOS + prefix) plus generation budget overflowing a slot."""
        return (len(req.tokens) > self._max_src
                or len(self._dec_prompt(req)) + req.max_new_tokens
                > self.cfg.max_len)

    def _dec_bucket(self, length: int) -> int:
        """Padded decoder-prompt program length: the BOS-only fast path
        compiles at 1; forced-decode prompts pad to the prefill bucket
        (clamped to the slot capacity)."""
        if length <= 1:
            return 1
        return min(self._bucketed(length), self.cfg.max_len)

    # ------------------------------------------------------------------
    # ragged-kernel decode bounds: enc-dec steps read two caches, so the
    # decode program carries a static bound for each — decoder KV (live
    # decoder-prompt + generated lengths) and cross-attention source cache
    # (live source lengths)
    # ------------------------------------------------------------------
    def _dec_len(self, req: Request) -> int:
        """Decoder-KV occupancy for the next dispatch: the decoder prompt
        is [bos] + forced prefix, not the source (``req.tokens``)."""
        return len(self._dec_prompt(req)) + req.scheduled

    def _src_bound(self) -> int:
        longest = max((len(r.tokens) for r in self._active.values()),
                      default=1)
        return min(_round_block(longest), self._max_src)

    def _decode_bounds(self) -> Tuple[int, ...]:
        if not self.cfg.use_kernels:
            return ()
        return (self._kv_bound(), self._src_bound())

    def _full_bounds(self) -> Tuple[int, ...]:
        if not self.cfg.use_kernels:
            return ()
        return (self.cfg.max_len, self._max_src)

    # ------------------------------------------------------------------
    # compiled executables: batched bucketed encode + per-slot prefill
    # (decode is inherited — the pooled cache carries the cross state)
    # ------------------------------------------------------------------
    def _encode_fn(self, params, tokens, lens):
        """(E, S_b) right-padded source tokens + (E,) valid lengths ->
        (E, S_b, d) encoder hidden states (bidirectional stack; token
        embeddings stand in for the stubbed audio frontend's precomputed
        frames).  ``lens`` masks each row's key padding, so valid rows are
        bit-identical across buckets."""
        return self.model.encode(params, {"tokens": tokens}, lens=lens)

    def _encode_frames_fn(self, params, frames, lens):
        """(E, S_b, d) right-padded precomputed frame embeddings + (E,)
        valid frame counts -> (E, S_b, d) encoder hidden states (a real
        frontend's output enters here instead of re-embedding tokens)."""
        return self.model.encode(params, {"frames": frames}, lens=lens)

    def _build_encode(self, mesh, sb: int, kind: str = TOKENS,
                      slots: Optional[int] = None):
        E = slots or self.cfg.max_slots
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = NamedSharding(mesh, P())
        if kind == FRAMES:
            fn = jax.jit(self._encode_frames_fn, **kwargs)
            src_aval = self._vec_aval(mesh, self.model.cfg.activation_dtype,
                                      (E, sb, self.model.cfg.d_model))
        else:
            fn = jax.jit(self._encode_fn, **kwargs)
            src_aval = self._vec_aval(mesh, jnp.int32, (E, sb))
        return fn.lower(
            self._param_plan.avals(mesh, self._rules_eff),
            src_aval,
            self._vec_aval(mesh, jnp.int32, (E,)),
        ).compile()

    def _encdec_prefill_fn(self, params, pool_cache, single, enc, idx,
                           src_len, slot, dec_toks, dec_len):
        """Write one encoded job into its slot: row ``idx`` of the batched
        encoder output becomes the slot's cross K/V (masked at ``src_len``),
        and a decoder prefill over the (padded) decoder prompt — BOS plus
        any forced-decoding prefix, valid length ``dec_len`` — seeds the
        slot's KV and the first generated token."""
        enc_row = jax.lax.dynamic_slice_in_dim(enc, idx, 1, axis=0)
        logits, filled = self.model.prefill(
            params, {"tokens": dec_toks}, single, enc_out=enc_row,
            src_len=src_len, true_len=dec_len)
        pool = _write_slot(pool_cache, filled, slot, self._slot_axes)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return first, pool

    def _build_prefill_encdec(self, mesh, sb: int, nb: int,
                              slots: Optional[int] = None):
        E = slots or self.cfg.max_slots
        plan = self._plan_for_slots(E)
        rules = self._rules_eff
        kwargs = {}
        if mesh is not None:
            kwargs["out_shardings"] = (
                NamedSharding(mesh, P()),
                plan.shardings(mesh, rules))
        fn = jax.jit(self._encdec_prefill_fn, donate_argnums=(1,), **kwargs)
        act = self.model.cfg.activation_dtype
        return fn.lower(
            self._param_plan.avals(mesh, rules),
            plan.avals(mesh, rules),
            self._single_plan.avals(mesh, rules),
            self._vec_aval(mesh, act, (E, sb, self.model.cfg.d_model)),
            self._vec_aval(mesh, jnp.int32, ()),
            self._vec_aval(mesh, jnp.int32, ()),
            self._vec_aval(mesh, jnp.int32, ()),
            self._vec_aval(mesh, jnp.int32, (1, nb)),
            self._vec_aval(mesh, jnp.int32, ()),
        ).compile()

    def _encode_exec(self, mesh, sb: int, kind: str = TOKENS):
        key = ("encdec_encode", self._cfg_key, self._mesh_fp, sb, kind)
        self._src_kinds.add(kind)
        return self._exec.get_or_build(
            key, self._counted(lambda: self._build_encode(mesh, sb, kind)))

    def _prefill_exec_encdec(self, mesh, sb: int, nb: int):
        key = ("encdec_prefill", self._cfg_key, self._mesh_fp, sb, nb)
        self._dec_lens.add(nb)
        return self._exec.get_or_build(
            key, self._counted(
                lambda: self._build_prefill_encdec(mesh, sb, nb)))

    def warm_compile(self, sub, point=None) -> int:
        """Pre-compile decode plus every (bucket, source kind, decoder
        prompt length) encode/prefill program for a candidate
        sub-accelerator — at a candidate *design point* when one is given
        (prospective slot count / TP degree / bucket ladder) — without
        moving any state.  The ladder and the observed decoder-prompt
        lengths are finite, so this fully covers the composition.  Returns
        the number of cold builds performed."""
        point = point if point is not None else DesignPoint(cus=0)
        with self._obs.timed("warm_compile", "warm_compile_s") as sp:
            mesh = part.tp_submesh(
                _mesh_of(sub), point.tp if point.tp is not None else self._tp)
            E = point.slots or self.cfg.max_slots
            key = self._config_key(E, point.buckets)
            ladder = (length_buckets(point.buckets, self._max_src)
                      if point.buckets is not None else self._src_buckets)
            fp = mesh_fingerprint(mesh)
            built = 0
            for bounds in sorted({self._decode_bounds(), self._next_bounds(),
                                  self._full_bounds()}):
                built += self._exec.ensure(
                    ("decode", key, fp, bounds),
                    self._counted(
                        lambda bounds=bounds:
                        self._build_decode(mesh, E, bounds)))
            # snapshots: the serving thread may add kinds/lengths while a
            # background prewarm iterates
            kinds = sorted(self._src_kinds)
            dec_lens = sorted(self._dec_lens)
            for sb in ladder:
                for kind in kinds:
                    built += self._exec.ensure(
                        ("encdec_encode", key, fp, sb, kind),
                        self._counted(
                            lambda sb=sb, kind=kind:
                            self._build_encode(mesh, sb, kind, E)))
                for nb in dec_lens:
                    built += self._exec.ensure(
                        ("encdec_prefill", key, fp, sb, nb),
                        self._counted(
                            lambda sb=sb, nb=nb:
                            self._build_prefill_encdec(mesh, sb, nb, E)))
            if sp is not None:
                sp["builds"] = built
        return built

    # ------------------------------------------------------------------
    # design-point knobs (serving DSE Stage 1)
    # ------------------------------------------------------------------
    def design(self) -> Dict[str, Any]:
        out = super().design()
        out["buckets"] = self._src_buckets
        return out

    def _apply_buckets(self, buckets):
        """Swap the source-length program ladder live.  Numerics-safe:
        encodes mask their key padding, so a job's stream is identical in
        any bucket — only the padded-FLOP profile changes."""
        if buckets is None:
            return None
        ladder = length_buckets(buckets, self._max_src)
        if ladder == self._src_buckets:
            return None
        self._src_buckets = ladder
        self._bucket_hits = {b: self._bucket_hits.get(b, 0) for b in ladder}
        self._cfg_key = self._config_key(self.cfg.max_slots)
        return ladder

    # ------------------------------------------------------------------
    # work ingestion: token or precomputed-frame sources, forced prefixes
    # ------------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 16, *,
               prefix=None) -> int:
        """Queue one encode→decode job; returns its rid.

        ``tokens`` is the SOURCE sequence: int token ids (embedded as
        stand-in frames — frontend STUB) or a float (S, d_model) array of
        precomputed frame embeddings.  ``prefix`` forces decoding: the
        decoder prompt becomes [bos] + prefix before generation starts.
        Requests never vanish: oversized ones are rejected-but-recorded."""
        rid = self._next_rid
        self._next_rid += 1
        src = np.asarray(tokens)
        if src.ndim == 2:                      # precomputed frame embeddings
            src = src.astype(np.dtype(self.model.cfg.activation_dtype))
        else:
            src = src.astype(np.int32)
        pre = None
        if prefix is not None and len(prefix) > 0:
            pre = np.asarray(prefix, np.int32)
        self._recent_lens.append(len(src))
        self._queue.append(Request(rid, src, max_new_tokens, prefix=pre,
                                   submitted_s=time.perf_counter()))
        self._obs.inc("requests_submitted")
        return rid

    # ------------------------------------------------------------------
    # admission: one batched encode per (bucket, kind) group, then
    # per-slot fused prefills
    # ------------------------------------------------------------------
    def _prefill_admitted(self, reqs: List[Request]) -> None:
        by_group: Dict[Tuple[int, str], List[Request]] = {}
        for req in reqs:
            kind = FRAMES if req.tokens.ndim == 2 else TOKENS
            sb = pick_bucket(self._src_buckets, len(req.tokens))
            by_group.setdefault((sb, kind), []).append(req)
        E = self.cfg.max_slots
        d = self.model.cfg.d_model
        act = np.dtype(self.model.cfg.activation_dtype)
        for sb, kind in sorted(by_group):
            group = by_group[(sb, kind)]
            for at in range(0, len(group), E):
                chunk = group[at:at + E]
                if kind == FRAMES:
                    src = np.zeros((E, sb, d), act)
                else:
                    src = np.zeros((E, sb), np.int32)
                lens = np.zeros((E,), np.int32)
                for i, req in enumerate(chunk):
                    src[i, :len(req.tokens)] = req.tokens
                    lens[i] = len(req.tokens)
                # dispatch-only span: the batched encode syncs later, at
                # each request's fused-prefill device_get (existing sync
                # point), so the encode_s histogram lives on the prefill
                # side and this span only attributes the dispatch
                with self._obs.span("encode", bucket=sb, kind=kind,
                                    n=len(chunk)):
                    enc = self._encode_exec(self.mesh, sb, kind)(
                        self.params, src, lens)
                for i, req in enumerate(chunk):
                    self._bucket_hits[sb] += 1
                    dec = self._dec_prompt(req)
                    nb = self._dec_bucket(len(dec))
                    toks = np.zeros((1, nb), np.int32)
                    toks[0, :len(dec)] = dec
                    with self._obs.timed("prefill", "prefill_s",
                                         src=len(req.tokens)):
                        exe = self._prefill_exec_encdec(self.mesh, sb, nb)
                        first_dev, self.cache = exe(
                            self.params, self.cache, self._single, enc,
                            np.int32(i), np.int32(len(req.tokens)),
                            np.int32(req.slot), toks, np.int32(len(dec)))
                        first = int(jax.device_get(first_dev))
                    req.out_tokens.append(first)
                    req.scheduled = 1
                    self._inject[req.slot] = first
                    self._record_ttft(req)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Base decode-engine stats plus per-bucket encode-program hit
        counts (jobs served per source-length bucket)."""
        out = super().stats()
        out["bucket_hits"] = {str(b): n for b, n in self._bucket_hits.items()}
        return out
