"""Code generator: ExecutionPlan -> per-function-unit instruction streams
(paper Fig. 6 "Code Generator / Instruction Generator" and §2.5/Table 1).

DDR layout convention (the "ready-to-run binary" addressing):
  * every layer's weight operand (B matrix) gets a static DDR region;
  * every layer's result (C) gets a DDR region, which downstream layers load
    as their activation operand (A);
  * layer 0's activation input is the workload input region.

Per scheduled layer the emitted program is:
  IOMLoad  A -> fmu_ids[0]          FMU(A): RECV_IOM, then SEND_CU window
  IOMLoad  B -> fmu_ids[1]          FMU(B): RECV_IOM, then SEND_CU window
  CU(each cu_id): OP_MM with packed runtime (m,k,n) atom bounds — the
      flexible-parallelism instruction; rows are split across the CUs
  FMU(C = fmu_ids[2]): RECV_CU, then IOMStore C -> DDR

The functional simulator (repro.core.simulator) executes these streams
against numpy DDR/arena state and must reproduce the workload's reference
numerics — the end-to-end test of ISA + arena + kernel semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.common.platform import PlatformProfile, VCK190
from repro.configs.paper_workloads import MMWorkload
from repro.core import instructions as isa
from repro.core.dse import ExecutionPlan, PlannedLayer


@dataclasses.dataclass(frozen=True)
class DDRLayout:
    """Element offsets of every operand region in DDR."""

    input_addr: int
    weight_addr: Dict[int, int]       # layer -> B-matrix region
    result_addr: Dict[int, int]       # layer -> C-matrix region
    total_elems: int


def plan_ddr_layout(workload: MMWorkload) -> DDRLayout:
    cursor = 0
    first = workload.layers[0]
    input_addr = cursor
    cursor += first.m * first.k
    weight_addr, result_addr = {}, {}
    for i, l in enumerate(workload.layers):
        weight_addr[i] = cursor
        cursor += l.k * l.n
    for i, l in enumerate(workload.layers):
        result_addr[i] = cursor
        cursor += l.m * l.n
    return DDRLayout(input_addr, weight_addr, result_addr, cursor)


@dataclasses.dataclass(frozen=True)
class CUWork:
    """One CU pass: (cu_id, compute instr, A-send, B-send, C-recv)."""

    cu_id: int
    compute: isa.CUInstr
    send_a: isa.FMUInstr
    send_b: isa.FMUInstr
    recv_c: isa.FMUInstr


@dataclasses.dataclass(frozen=True)
class LayerProgram:
    """The micro-program of one scheduled layer, in dataflow order."""

    layer: int
    loads: Tuple[isa.IOMLoad, ...]
    recv_iom: Tuple[Tuple[int, isa.FMUInstr], ...]   # (fmu_id, instr)
    cu_work: Tuple[CUWork, ...]
    fmu_c: int
    store: isa.IOMStore


@dataclasses.dataclass
class Program:
    """Instruction streams per function unit (+ generator header blocks) and
    the layer-ordered micro-programs the simulator replays."""

    gen: List[isa.InstrGen]
    iom_load: List[isa.IOMLoad]
    iom_store: List[isa.IOMStore]
    fmu: Dict[int, List[isa.FMUInstr]]
    cu: Dict[int, List[isa.CUInstr]]
    layout: DDRLayout
    layer_programs: List[LayerProgram] = dataclasses.field(default_factory=list)

    def total_bytes(self) -> int:
        n = isa.stream_bytes(self.gen) + isa.stream_bytes(self.iom_load) \
            + isa.stream_bytes(self.iom_store)
        for s in self.fmu.values():
            n += isa.stream_bytes(s)
        for s in self.cu.values():
            n += isa.stream_bytes(s)
        return n


def _a_source(workload: MMWorkload, layout: DDRLayout, li: int) -> int:
    """Activation operand region: the first dependency whose result shape
    matches this layer's (m, k) A operand; otherwise the workload input
    region (layers fed through reshapes/pools — PointNet's T-Net applies —
    consume an external tensor; the dependency still gates scheduling)."""
    layer = workload.layers[li]
    for d in layer.deps:
        dep = workload.layers[d]
        if (dep.m, dep.n) == (layer.m, layer.k):
            return layout.result_addr[d]
    return layout.input_addr


def generate(workload: MMWorkload, plan: ExecutionPlan,
             platform: PlatformProfile = VCK190) -> Program:
    layout = plan_ddr_layout(workload)
    am, ak, an = platform.atom_shape
    prog = Program(gen=[], iom_load=[], iom_store=[], fmu={}, cu={},
                   layout=layout)

    def fmu_stream(u: int) -> List[isa.FMUInstr]:
        return prog.fmu.setdefault(u, [])

    def cu_stream(u: int) -> List[isa.CUInstr]:
        return prog.cu.setdefault(u, [])

    ordered = sorted(plan.layers, key=lambda p: (p.start, p.layer))
    for pl in ordered:
        li = pl.layer
        m, k, n = pl.mkn
        assert len(pl.fmu_ids) >= 3, "layer needs A/B/C FMU views"
        fa, fb, fc = pl.fmu_ids[0], pl.fmu_ids[1], pl.fmu_ids[2]

        # --- IOM loads ---------------------------------------------------
        load_a = isa.IOMLoad(
            is_last=False, ddr_addr=_a_source(workload, layout, li),
            des_fmu=fa, m=m, n=k, start_row=0, end_row=m,
            start_col=0, end_col=k)
        load_b = isa.IOMLoad(
            is_last=False, ddr_addr=layout.weight_addr[li],
            des_fmu=fb, m=k, n=n, start_row=0, end_row=k,
            start_col=0, end_col=n)
        prog.iom_load += [load_a, load_b]

        # --- FMU receive + send views (FMV: 1-D windows) ------------------
        recv_a = isa.FMUInstr(
            is_last=False, ping_op=isa.OP_RECV_IOM, pong_op=isa.OP_NOP,
            src_cu=0, des_cu=pl.cu_ids[0], count=m * k,
            start_row=0, end_row=m, start_col=0, end_col=k, view_cols=k)
        recv_b = isa.FMUInstr(
            is_last=False, ping_op=isa.OP_RECV_IOM, pong_op=isa.OP_NOP,
            src_cu=0, des_cu=pl.cu_ids[0], count=k * n,
            start_row=0, end_row=k, start_col=0, end_col=n, view_cols=n)
        fmu_stream(fa).append(recv_a)
        fmu_stream(fb).append(recv_b)

        # --- CU compute: rows split across the allocated CUs --------------
        ncu = len(pl.cu_ids)
        rows_per = -(-m // ncu)
        work: List[CUWork] = []
        for ci, cu_id in enumerate(pl.cu_ids):
            r0 = ci * rows_per
            r1 = min(m, r0 + rows_per)
            if r0 >= r1:
                continue
            send_a = isa.FMUInstr(
                is_last=False, ping_op=isa.OP_SEND_CU, pong_op=isa.OP_NOP,
                src_cu=0, des_cu=cu_id, count=(r1 - r0) * k,
                start_row=r0, end_row=r1, start_col=0, end_col=k,
                view_cols=k)
            send_b = isa.FMUInstr(
                is_last=False, ping_op=isa.OP_SEND_CU, pong_op=isa.OP_NOP,
                src_cu=0, des_cu=cu_id, count=k * n,
                start_row=0, end_row=k, start_col=0, end_col=n,
                view_cols=n)
            compute = isa.CUInstr(
                is_last=False, ping_op=isa.OP_MM, pong_op=isa.OP_NOP,
                src_fmu=fa, des_fmu=fc,
                count=isa.pack_mkn(-(-(r1 - r0) // am), -(-k // ak),
                                   -(-n // an)),
                src_fmu_b=fb)
            recv_c = isa.FMUInstr(
                is_last=False, ping_op=isa.OP_RECV_CU, pong_op=isa.OP_NOP,
                src_cu=cu_id, des_cu=0, count=(r1 - r0) * n,
                start_row=r0, end_row=r1, start_col=0, end_col=n,
                view_cols=n)
            fmu_stream(fa).append(send_a)
            fmu_stream(fb).append(send_b)
            cu_stream(cu_id).append(compute)
            fmu_stream(fc).append(recv_c)
            work.append(CUWork(cu_id, compute, send_a, send_b, recv_c))

        # --- result store --------------------------------------------------
        store_c = isa.IOMStore(
            is_last=False, ddr_addr=layout.result_addr[li], src_fmu=fc,
            m=m, n=n, start_row=0, end_row=m, start_col=0, end_col=n)
        prog.iom_store.append(store_c)
        prog.layer_programs.append(LayerProgram(
            layer=li, loads=(load_a, load_b),
            recv_iom=((fa, recv_a), (fb, recv_b)), cu_work=tuple(work),
            fmu_c=fc, store=store_c))

    # mark stream tails + generator headers
    def _finalize(stream):
        if stream:
            stream[-1] = dataclasses.replace(stream[-1], is_last=True)

    _finalize(prog.iom_load)
    _finalize(prog.iom_store)
    for s in prog.fmu.values():
        _finalize(s)
    for s in prog.cu.values():
        _finalize(s)
    prog.gen.append(isa.InstrGen(False, isa.UNIT_IOM_LOAD,
                                 len(prog.iom_load)))
    prog.gen.append(isa.InstrGen(False, isa.UNIT_IOM_STORE,
                                 len(prog.iom_store)))
    for u, s in sorted(prog.fmu.items()):
        prog.gen.append(isa.InstrGen(False, isa.UNIT_FMU, len(s)))
    for u, s in sorted(prog.cu.items()):
        prog.gen.append(isa.InstrGen(False, isa.UNIT_CU, len(s)))
    _finalize(prog.gen)
    return prog
