"""Substrate tests: optimizers, gradient compression, data pipeline
determinism, checkpoint roundtrip + elastic restore, fault machinery,
serving engine vs offline decode, MoE dispatch equivalence."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticLM
from repro.distribution import strip
from repro.models import build_model
from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         cosine_schedule, dequantize_int8, quantize_int8)
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer, checkpoint as ck, fault


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _rosenbrock_ish(params):
    return jnp.sum(jnp.square(params["w"] - 3.0)) + \
        jnp.sum(jnp.square(params["b"] + 1.0))


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizers_converge(make_opt):
    opt = make_opt()
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    loss0 = float(_rosenbrock_ish(params))
    for i in range(200):
        grads = jax.grad(_rosenbrock_ish)(params)
        params, state = opt.update(grads, state, params, 5e-2)
    assert float(_rosenbrock_ish(params)) < loss0 * 0.05


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32))}
    state = opt.init(params)
    v = state["v"]["w"]
    assert v["vr"].shape == (64,) and v["vc"].shape == (32,)
    # vs adamw's full second moment
    full = adamw().init(params)
    assert full["v"]["w"].shape == (64, 32)


def test_global_norm_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 1.0
    _, norm2 = clip_by_global_norm(clipped, 1.0)
    assert float(norm2) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=0.05)


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 5,
                    jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.51   # half-ulp of the quant grid


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    p1 = SyntheticLM(cfg)
    p2 = SyntheticLM(cfg)
    b5a = p1.batch(5)
    # restart: a fresh pipeline reproduces step 5 exactly
    for s in (0, 3):
        p2.batch(s)
    np.testing.assert_array_equal(b5a["tokens"], p2.batch(5)["tokens"])
    assert b5a["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b5a["labels"][:, :-1], b5a["tokens"][:, 1:])


def test_pipeline_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=1)
    full = SyntheticLM(cfg).batch(2)["tokens"]
    h0 = SyntheticLM(cfg, host_id=0, num_hosts=2).batch(2)["tokens"]
    h1 = SyntheticLM(cfg, host_id=1, num_hosts=2).batch(2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.asarray([1, 2, 3])}}
    with tempfile.TemporaryDirectory() as d:
        assert ck.latest_step(d) is None
        ck.save(d, 3, tree, extra={"next_step": 3})
        ck.save(d, 7, tree, extra={"next_step": 7})
        assert ck.latest_step(d) == 7
        got, extra = ck.restore(d, 7, tree)
        assert extra["next_step"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(a, b)


def test_checkpoint_atomicity_ignores_partial():
    tree = {"a": jnp.zeros(3)}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, tree)
        os.makedirs(os.path.join(d, "step_00000005"))   # no manifest: partial
        assert ck.latest_step(d) == 1


# ---------------------------------------------------------------------------
# fault machinery
# ---------------------------------------------------------------------------

def test_straggler_watchdog_flags_runs_not_blips():
    wd = fault.StragglerWatchdog(threshold=2.0, patience=3, warmup=4)
    actions = [wd.observe(i, 1.0) for i in range(8)]
    assert set(actions) == {fault.ACTION_NONE}
    assert wd.observe(8, 5.0) == fault.ACTION_WARN          # blip
    assert wd.observe(9, 1.0) == fault.ACTION_NONE          # recovered
    a = [wd.observe(10 + i, 5.0) for i in range(3)]
    assert a[-1] == fault.ACTION_CHECKPOINT_AND_RESHARD     # degraded host


def test_preemption_flag_file(tmp_path):
    flag = tmp_path / "preempt"
    g = fault.PreemptionGuard(flag_file=str(flag), install_signal=False)
    assert not g.check()
    flag.write_text("now")
    assert g.check()


def test_restart_policy_backoff():
    p = fault.RestartPolicy(max_restarts=3, base_backoff_s=1.0,
                            max_backoff_s=3.0)
    assert p.next_backoff() == 1.0
    assert p.next_backoff() == 2.0
    assert p.next_backoff() == 3.0
    assert p.next_backoff() is None


def test_trainer_preemption_checkpoints_and_resumes():
    from repro.data import make_pipeline
    cfg = get_reduced("minitron-4b")
    model = build_model(cfg)
    pipe = make_pipeline(cfg, seq_len=16, global_batch=2)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=10, lr=1e-3, warmup=1, checkpoint_every=100,
                         ckpt_dir=d, log_every=1)
        tr = Trainer(model, tc, mesh=None, pipeline=pipe)
        params, opt_state = tr.init_state()
        tr.guard.requested = False
        # preempt after 3 steps
        orig_check = tr.guard.check
        counter = {"n": 0}

        def fake_check():
            counter["n"] += 1
            return counter["n"] > 3

        tr.guard.check = fake_check
        out = tr.fit(params, opt_state, 0)
        assert out["status"] == "preempted"
        assert ck.latest_step(d) == out["step"]
        # resume completes the run
        tr2 = Trainer(model, tc, mesh=None, pipeline=pipe)
        out2 = tr2.fit()
        assert out2["status"] == "completed"
        assert out2["step"] == 10


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_matches_offline_greedy():
    cfg = get_reduced("qwen2.5-32b")
    m = build_model(cfg)
    params = strip(m.init(jax.random.key(0)))
    eng = ServeEngine(m, params, ServeConfig(max_slots=3, max_len=48,
                                             eos_id=-1, prefill_bucket=8))
    reqs = []
    for l in (5, 9, 13, 7):
        toks = np.arange(1, 1 + l) % cfg.vocab_size
        eng.submit(toks, max_new_tokens=5)
        reqs.append(toks)
    submitted = list(eng._queue)
    for _ in range(60):
        if not eng._queue and not eng._active:
            break
        eng.step()
    for req in submitted:
        cache = strip(m.init_cache(1, 48))
        logits, cache = m.prefill(params,
                                  {"tokens": jnp.asarray(req.tokens)[None]},
                                  cache)
        seq = [int(jnp.argmax(logits[0]))]
        for _ in range(4):
            logits, cache = m.decode_step(
                params, cache, jnp.asarray([[seq[-1]]], jnp.int32))
            seq.append(int(jnp.argmax(logits[0])))
        assert req.out_tokens == seq, (len(req.tokens), req.out_tokens, seq)


def test_engine_ssm_arch_exact_prefill():
    cfg = get_reduced("falcon-mamba-7b")
    m = build_model(cfg)
    params = strip(m.init(jax.random.key(0)))
    eng = ServeEngine(m, params, ServeConfig(max_slots=2, max_len=32,
                                             eos_id=-1))
    eng.submit(np.arange(1, 7), max_new_tokens=4)
    submitted = list(eng._queue)
    for _ in range(20):
        if not eng._queue and not eng._active:
            break
        eng.step()
    req = submitted[0]
    assert len(req.out_tokens) == 4
    cache = strip(m.init_cache(1, 32))
    logits, cache = m.prefill(params, {"tokens": jnp.asarray(req.tokens)[None]},
                              cache)
    assert req.out_tokens[0] == int(jnp.argmax(logits[0]))


def test_engine_admission_control():
    cfg = get_reduced("qwen2.5-32b")
    m = build_model(cfg)
    params = strip(m.init(jax.random.key(0)))
    eng = ServeEngine(m, params, ServeConfig(max_slots=2, max_len=16,
                                             eos_id=-1))
    # longer than max_len: rejected without crashing
    eng.submit(np.arange(1, 40), max_new_tokens=4)
    eng.step()
    assert not eng._active


# ---------------------------------------------------------------------------
# MoE dispatch equivalence
# ---------------------------------------------------------------------------

def test_moe_einsum_equals_gather_dispatch():
    from repro.models import moe as M
    cfg = get_reduced("deepseek-v2-lite-16b")
    p = strip(M.moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    y1, a1 = M.moe_apply(p, cfg, x, dispatch_impl="einsum")
    y2, a2 = M.moe_apply(p, cfg, x, dispatch_impl="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    assert float(a1) == pytest.approx(float(a2))


def test_moe_capacity_drops_tokens():
    import dataclasses

    from repro.models import moe as M
    cfg = get_reduced("arctic-480b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    p = strip(M.moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    y_low, _ = M.moe_apply(p, cfg, x, dispatch_impl="einsum")
    cfg_hi = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    y_hi, _ = M.moe_apply(p, cfg_hi, x, dispatch_impl="einsum")
    assert float(jnp.abs(y_hi - y_low).max()) > 1e-4
