"""Pure-jnp oracle for the flash_attention kernel (naive materialized attn)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q, k, v: (BH, S, D).  Naive O(S^2) reference."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
