"""falcon-mamba-7b — attention-free Mamba-1 [arXiv:2410.05355].

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
Pure Mamba-1 blocks: the mixer *is* the FFN (d_inner = 2*d_model), so d_ff=0.
`long_500k` runs (O(1) recurrent state).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    attn_type="none",
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    act="silu",
    glu=False,
)

REDUCED = ModelConfig(
    name="falcon-mamba-reduced",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    attn_type="none",
    ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
    act="silu",
    glu=False,
)
