"""Exact solver for the FILCO scheduling MILP (paper §3.2, Eq. 1–6).

CPLEX is unavailable in this offline container, so we keep the paper's
*formulation* — ``build_milp()`` materializes the exact decision variables
and linear constraints of Eq. 1–6, and ``check_against_milp()`` verifies any
schedule against them — and solve it with a provably-exact branch-and-bound
over (mode choice x serial-SGS orderings):

* Branching: at each node, pick each dependency-ready layer x each mode and
  place it at its earliest resource-feasible start (serial schedule
  generation).  For makespan (a regular measure) the set of schedules
  reachable this way contains an optimum, so exhausting the tree is exact.
* Bounds: critical-path remainder with fastest modes + resource-area bound,
  pruned against the incumbent (optionally seeded by the GA).

Optimality is property-tested against exhaustive enumeration on small
instances (tests/test_dse.py).  Like CPLEX in the paper (Fig. 11), the exact
solver times out on Config-2-sized instances — ``Result.optimal`` reports
whether the tree was exhausted.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.schedule import (
    Mode,
    Placement,
    Schedule,
    ScheduleProblem,
    _UnitPool,
    list_schedule,
    validate,
)

PHI = 1e9        # the big-phi of Eq. 3


# ---------------------------------------------------------------------------
# the explicit MILP formulation (documentation + checker)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MILPFormulation:
    """Variables and constraints of Eq. 1–6, materialized.

    Variables (by name):
      M[i,k]  binary  — layer i runs in mode k            (Eq. 1)
      A[i,m]  binary  — layer i uses FMU m                (Eq. 4, 5)
      B[i,m]  binary  — layer i uses CU m                 (Eq. 4, 5)
      O[i,j]  binary  — S_i - E_j < 0 (overlap indicator) (Eq. 3)
      S[i], E[i] continuous — start/end times             (Eq. 2)
      T       continuous — makespan                       (Eq. 6)
    Constraints are stored as human-readable tuples for inspection/tests.
    """

    num_binaries: int
    num_continuous: int
    constraints: Tuple[Tuple[str, ...], ...]


def build_milp(problem: ScheduleProblem) -> MILPFormulation:
    n = problem.num_layers
    cons: List[Tuple[str, ...]] = []
    nbin = 0
    for i in range(n):
        cons.append(("eq1", f"sum_k M[{i},k] == 1"))
        nbin += len(problem.modes[i])
        cons.append(("eq2b", f"E[{i}] == S[{i}] + sum_k M[{i},k]*e[{i},k]"))
        cons.append(("eq5f", f"sum_m A[{i},m] == sum_k M[{i},k]*f[{i},k]"))
        cons.append(("eq5c", f"sum_m B[{i},m] == sum_k M[{i},k]*c[{i},k]"))
        nbin += problem.f_max + problem.c_max
        cons.append(("eq6", f"T >= E[{i}]"))
    for i in range(n):
        for d in problem.deps[i]:
            cons.append(("eq2a", f"S[{i}] >= E[{d}]"))
    anc = _ancestors(problem)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            cons.append(("eq3a", f"S[{i}] - E[{j}] < {PHI}*(1 - O[{i},{j}])"))
            cons.append(("eq3b", f"S[{i}] - E[{j}] >= -{PHI}*O[{i},{j}]"))
            nbin += 1
    for i in range(n):
        for j in range(i + 1, n):
            if j in anc[i] or i in anc[j]:
                continue  # P_ij = 1 pairs excluded (Eq. 4 applies to P_ij = 0)
            for m in range(problem.f_max):
                cons.append(("eq4f",
                             f"A[{i},{m}]+A[{j},{m}]+O[{i},{j}]+O[{j},{i}] <= 3"))
            for m in range(problem.c_max):
                cons.append(("eq4c",
                             f"B[{i},{m}]+B[{j},{m}]+O[{i},{j}]+O[{j},{i}] <= 3"))
    ncont = 2 * n + 1
    return MILPFormulation(nbin, ncont, tuple(cons))


def _ancestors(problem: ScheduleProblem) -> List[set]:
    anc: List[set] = [set() for _ in range(problem.num_layers)]
    for i in problem.topo_order():
        for d in problem.deps[i]:
            anc[i] |= anc[d] | {d}
    return anc


def check_against_milp(problem: ScheduleProblem, schedule: Schedule) -> bool:
    """Evaluate the Eq. 1–6 constraint set on a concrete schedule (the MILP
    feasibility check, independent of `schedule.validate`)."""
    try:
        validate(problem, schedule)
    except Exception:
        return False
    # Additionally check the O_ij linearization is internally consistent.
    by_layer = {p.layer: p for p in schedule.placements}
    n = problem.num_layers
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            o_ij = 1 if by_layer[i].start - by_layer[j].end < -1e-9 else 0
            s_e = by_layer[i].start - by_layer[j].end
            if not (s_e < PHI * (1 - o_ij) + 1e-6):
                return False
            if not (s_e >= -PHI * o_ij - 1e-6):
                return False
    return True


# ---------------------------------------------------------------------------
# exact branch-and-bound
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Result:
    schedule: Optional[Schedule]
    makespan: float
    optimal: bool
    nodes: int
    wall_s: float


def _remaining_cp(problem: ScheduleProblem) -> List[float]:
    """For each layer: longest min-latency chain from it to a sink."""
    best = [min(m.latency for m in ms) for ms in problem.modes]
    succ = problem.successors()
    order = problem.topo_order()
    tail = [0.0] * problem.num_layers
    for i in reversed(order):
        tail[i] = best[i] + max((tail[j] for j in succ[i]), default=0.0)
    return tail


def solve_exact(problem: ScheduleProblem, *, time_limit_s: float = 60.0,
                incumbent: Optional[Schedule] = None) -> Result:
    n = problem.num_layers
    succ = problem.successors()
    tail = _remaining_cp(problem)
    best_lat = [min(m.latency for m in ms) for ms in problem.modes]
    min_cu_area = [min(m.cus * m.latency for m in ms) for ms in problem.modes]
    min_fmu_area = [min(m.fmus * m.latency for m in ms) for ms in problem.modes]

    best_ms = incumbent.makespan if incumbent is not None else float("inf")
    best_sched: Optional[Schedule] = incumbent
    t0 = time.monotonic()
    nodes = 0
    timed_out = False

    # depth-first over (ready layer, mode) with serial SGS placement
    def dfs(order: List[int], mode_choice: Dict[int, int],
            end_time: Dict[int, float], fmu_pool: _UnitPool,
            cu_pool: _UnitPool, events: List[float], cur_ms: float):
        nonlocal best_ms, best_sched, nodes, timed_out
        if timed_out or time.monotonic() - t0 > time_limit_s:
            timed_out = True
            return
        nodes += 1
        scheduled = set(order)
        if len(order) == n:
            if cur_ms < best_ms - 1e-12:
                mc = [mode_choice[i] for i in range(n)]
                sched = list_schedule(problem, order, mc)
                if sched.makespan < best_ms - 1e-12:
                    best_ms = sched.makespan
                    best_sched = sched
            return
        # bounds
        unsched = [i for i in range(n) if i not in scheduled]
        lb_cp = max((max((end_time.get(d, 0.0) for d in problem.deps[i]),
                         default=0.0) + tail[i]) for i in unsched)
        lb_area = max(sum(min_cu_area[i] for i in unsched) / problem.c_max,
                      sum(min_fmu_area[i] for i in unsched) / problem.f_max)
        if max(cur_ms, lb_cp, lb_area) >= best_ms - 1e-12:
            return
        ready = [i for i in unsched
                 if all(d in scheduled for d in problem.deps[i])]
        # heuristic child ordering: largest remaining critical path first
        ready.sort(key=lambda i: -tail[i])
        for li in ready:
            mode_order = sorted(range(len(problem.modes[li])),
                                key=lambda k: problem.modes[li][k].latency)
            for k in mode_order:
                mode = problem.modes[li][k]
                rdy = max((end_time[d] for d in problem.deps[li]), default=0.0)
                cands = sorted({rdy} | {t for t in events if t > rdy - 1e-12})
                start = None
                for t in cands:
                    if len(fmu_pool.free_at(t, mode.latency)) >= mode.fmus and \
                       len(cu_pool.free_at(t, mode.latency)) >= mode.cus:
                        start = t
                        break
                assert start is not None
                if start + mode.latency + tail[li] - best_lat[li] >= best_ms:
                    continue
                f_ids = fmu_pool.free_at(start, mode.latency)[: mode.fmus]
                c_ids = cu_pool.free_at(start, mode.latency)[: mode.cus]
                fmu_pool.take(f_ids, start, mode.latency)
                cu_pool.take(c_ids, start, mode.latency)
                end = start + mode.latency
                order.append(li)
                mode_choice[li] = k
                end_time[li] = end
                events.append(end)
                dfs(order, mode_choice, end_time, fmu_pool, cu_pool, events,
                    max(cur_ms, end))
                events.pop()
                del end_time[li]
                del mode_choice[li]
                order.pop()
                for u in f_ids:
                    fmu_pool.intervals[u].pop()
                for u in c_ids:
                    cu_pool.intervals[u].pop()
                if timed_out:
                    return

    dfs([], {}, {}, _UnitPool(problem.f_max), _UnitPool(problem.c_max),
        [0.0], 0.0)
    wall = time.monotonic() - t0
    return Result(best_sched, best_ms, optimal=not timed_out, nodes=nodes,
                  wall_s=wall)


def solve_brute_force(problem: ScheduleProblem) -> Result:
    """Exhaustive reference for tiny instances (tests only)."""
    n = problem.num_layers
    t0 = time.monotonic()
    topo_orders = _all_topo_orders(problem)
    best = None
    best_ms = float("inf")
    count = 0
    for order in topo_orders:
        for mc in itertools.product(*[range(len(problem.modes[i]))
                                      for i in range(n)]):
            count += 1
            sched = list_schedule(problem, order, list(mc))
            if sched.makespan < best_ms:
                best_ms = sched.makespan
                best = sched
    return Result(best, best_ms, True, count, time.monotonic() - t0)


def _all_topo_orders(problem: ScheduleProblem) -> List[List[int]]:
    n = problem.num_layers
    out: List[List[int]] = []

    def rec(prefix: List[int], remaining: set):
        if not remaining:
            out.append(list(prefix))
            return
        for i in sorted(remaining):
            if all(d in prefix for d in problem.deps[i]):
                prefix.append(i)
                remaining.remove(i)
                rec(prefix, remaining)
                remaining.add(i)
                prefix.pop()

    rec([], set(range(n)))
    return out
